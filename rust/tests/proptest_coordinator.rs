//! Property tests on coordinator invariants (routing, batching, state),
//! via the in-repo property runner (`testutil::forall` — the offline
//! stand-in for proptest, with seeded replay).

use star::cluster::{water_fill, water_fill_into, Cluster, ClusterConfig, Res, Role, Task};
use star::decide::{choose_ps_heuristic, expected_reports, time_to_progress_ps};
use star::driver::first_k_split;
use star::predict::{deviation_ratios, straggler_flags};
use star::prevent::{equalize_group, sensitivity_deprivation, CommTree, Victim};
use star::progress::ProgressModel;
use star::simrng::Rng;
use star::sync::{cluster_times, plan_round, SyncMode};
use star::testutil::forall;

fn times_gen(rng: &mut Rng) -> Vec<f64> {
    let n = rng.usize(2, 12);
    (0..n).map(|_| rng.range(0.05, 5.0)).collect()
}

#[test]
fn prop_every_plan_partitions_workers() {
    forall("plan-partition", 300, times_gen, |times| {
        let n = times.len();
        let mut rng = Rng::seeded(times.len() as u64);
        let modes = vec![
            SyncMode::Ssgd,
            SyncMode::Asgd,
            SyncMode::StaticX(rng.usize(1, n)),
            SyncMode::DynamicX,
            SyncMode::ArRing { removed: rng.usize(0, n - 1), tw_ms: rng.range(0.0, 300.0) },
        ];
        for mode in modes {
            let plan = plan_round(&mode, times, times);
            let mut seen = vec![0u32; n];
            for u in &plan.updates {
                for &m in &u.members {
                    seen[m] += 1;
                }
            }
            match mode {
                SyncMode::ArRing { .. } => {
                    // ring: each member at most once, ring members exactly once
                    if seen.iter().any(|&c| c > 1) {
                        return Err(format!("{mode:?}: duplicated member"));
                    }
                }
                _ => {
                    if seen.iter().any(|&c| c != 1) {
                        return Err(format!("{mode:?}: not a partition: {seen:?}"));
                    }
                }
            }
            // update times within [0, span]; worker_end >= own time for sync
            for u in &plan.updates {
                if u.at < 0.0 || u.at > plan.span + 1e-9 {
                    return Err(format!("{mode:?}: update at {} outside span {}", u.at, plan.span));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_round_conservation_across_all_modes() {
    // every gradient report is applied in exactly one update — or, for the
    // AR ring, explicitly left out when it misses the aggregation window —
    // and update batch sizes agree with `shrinks_batch`
    forall("round-conservation", 300, times_gen, |times| {
        let n = times.len();
        let mut rng = Rng::seeded(n as u64 ^ 0xC0FFEE);
        let modes = vec![
            SyncMode::Ssgd,
            SyncMode::Asgd,
            SyncMode::StaticX(rng.usize(1, n)),
            SyncMode::DynamicX,
            SyncMode::ArRing { removed: rng.usize(0, n - 1), tw_ms: rng.range(0.0, 300.0) },
        ];
        for mode in modes {
            let p = plan_round(&mode, times, times);
            let used: usize = p.updates.iter().map(|u| u.members.len()).sum();
            if used != p.reports_used {
                return Err(format!(
                    "{mode:?}: reports_used {} != member total {used}",
                    p.reports_used
                ));
            }
            let mut seen = vec![false; n];
            for u in &p.updates {
                if u.members.is_empty() {
                    return Err(format!("{mode:?}: empty update"));
                }
                for &m in &u.members {
                    if seen[m] {
                        return Err(format!("{mode:?}: worker {m} applied twice"));
                    }
                    seen[m] = true;
                }
            }
            let applied = seen.iter().filter(|&&s| s).count();
            match &mode {
                SyncMode::ArRing { removed, .. } => {
                    // ring members always apply; removed stragglers apply
                    // iff they beat the window — the rest are the
                    // explicitly dropped set
                    let removed = (*removed).min(n - 1);
                    if applied < n - removed {
                        return Err(format!("{mode:?}: a ring member's report vanished"));
                    }
                    if n - applied > removed {
                        return Err(format!("{mode:?}: dropped more than the removed set"));
                    }
                }
                _ => {
                    if applied != n {
                        return Err(format!(
                            "{mode:?}: {applied}/{n} reports applied (none may drop)"
                        ));
                    }
                }
            }
            // batch sizes vs shrinks_batch
            let max_batch = p.updates.iter().map(|u| u.members.len()).max().unwrap_or(0);
            if max_batch > n {
                return Err(format!("{mode:?}: batch {max_batch} > {n}"));
            }
            if !mode.shrinks_batch(n) && p.updates.iter().any(|u| u.members.len() != n) {
                return Err(format!(
                    "{mode:?}: claims the full batch but fired a partial update"
                ));
            }
            match &mode {
                SyncMode::Asgd if n > 1 => {
                    if max_batch != 1 {
                        return Err("ASGD: batch must be exactly one report".into());
                    }
                }
                SyncMode::StaticX(x) if *x < n => {
                    if max_batch > *x {
                        return Err(format!("{x}-order: batch {max_batch} > x"));
                    }
                }
                _ => {}
            }
        }
        Ok(())
    });
}

#[test]
fn prop_first_k_applies_k_and_drops_the_rest() {
    // the driver's LGC first-K rule: once K live reports have arrived,
    // the first K (by arrival) form the update and the rest are
    // explicitly dropped — nothing is lost, nothing applied twice
    forall(
        "first-k",
        300,
        |rng| {
            let n = rng.usize(1, 12);
            let k = rng.usize(1, 14);
            let live = rng.usize(1, n);
            let mut workers: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut workers);
            let arrived = rng.usize(0, n);
            workers.truncate(arrived);
            (workers, k, live)
        },
        |(arrival, k, live)| {
            let (members, dropped) = first_k_split(arrival, *k, *live);
            let kk = (*k).clamp(1, (*live).max(1));
            if arrival.len() < kk {
                if !members.is_empty() || !dropped.is_empty() {
                    return Err("below threshold: all reports must stay pending".into());
                }
                return Ok(());
            }
            if members.len() != kk {
                return Err(format!("update batch {} != clamped K {kk}", members.len()));
            }
            if members[..] != arrival[..kk] {
                return Err("members must be the first K arrivals".into());
            }
            // conservation: members ++ dropped is exactly the arrival set
            let mut all = members.clone();
            all.extend(dropped.iter().copied());
            if all != *arrival {
                return Err(format!(
                    "report lost or duplicated: {all:?} vs {arrival:?}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_heuristic_pick_is_argmin_of_its_ranking() {
    forall("heuristic-argmin", 200, times_gen, |times| {
        let n = times.len();
        let spec = &star::models::ZOO[times.len() % 10];
        let d = choose_ps_heuristic(spec, 50.0, n, times);
        for (m, est) in &d.ranked {
            if *est < d.est - 1e-12 {
                return Err(format!("{} beats chosen {}", m.name(), d.mode.name()));
            }
        }
        // chosen estimate must equal a recomputed one (determinism)
        let again = time_to_progress_ps(spec, 50.0, n, &d.mode, times);
        if (again - d.est).abs() > 1e-9 {
            return Err("estimate not reproducible".into());
        }
        Ok(())
    });
}

#[test]
fn prop_expected_reports_bounded() {
    forall("reports-bounds", 200, times_gen, |times| {
        let n = times.len();
        for mode in star::sync::candidate_modes_ps(n) {
            let r = expected_reports(n, &mode, times);
            if r < 1 || r > n as u64 {
                return Err(format!("{}: reports {r} outside [1,{n}]", mode.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_water_fill_conserves_and_caps() {
    forall(
        "water-fill",
        300,
        |rng| {
            let n = rng.usize(0, 16);
            let demands: Vec<f64> = (0..n).map(|_| rng.range(0.0, 10.0)).collect();
            let cap = rng.range(0.0, 40.0);
            (demands, cap)
        },
        |(demands, cap)| {
            let a = water_fill(demands, *cap);
            let sum: f64 = a.iter().sum();
            let dem: f64 = demands.iter().sum();
            // contended regime: the allocation must not exceed capacity
            if dem > cap + 1e-9 && sum > cap + 1e-9 {
                return Err(format!("over-allocated: {sum} vs cap {cap}"));
            }
            for (x, d) in a.iter().zip(demands) {
                if *x > d + 1e-9 || *x < -1e-12 {
                    return Err(format!("share {x} vs demand {d}"));
                }
            }
            // max-min fairness: if any task got less than demand, no task
            // got more than (max unmet task's share + epsilon) while having
            // lower demand... simplified check: unmet tasks share equally
            let unmet: Vec<f64> = a
                .iter()
                .zip(demands)
                .filter(|(x, d)| **x < *d - 1e-9)
                .map(|(x, _)| *x)
                .collect();
            if unmet.len() >= 2 {
                let lo = unmet.iter().cloned().fold(f64::INFINITY, f64::min);
                let hi = unmet.iter().cloned().fold(0.0, f64::max);
                if hi - lo > 1e-6 {
                    return Err(format!("unmet shares unequal: {lo} vs {hi}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_water_fill_into_conserves_caps_and_matches() {
    forall(
        "water-fill-into",
        300,
        |rng| {
            let n = rng.usize(0, 16);
            let demands: Vec<f64> = (0..n).map(|_| rng.range(0.0, 10.0)).collect();
            let cap = rng.range(0.0, 40.0);
            (demands, cap)
        },
        |(demands, cap)| {
            let mut order = Vec::new();
            let mut a = Vec::new();
            water_fill_into(demands, *cap, &mut order, &mut a);
            // bit-identical to the allocating variant (same sort, same ties)
            if a != water_fill(demands, *cap) {
                return Err("water_fill_into diverges from water_fill".into());
            }
            // reusing dirty scratch buffers must not change the result
            let first = a.clone();
            water_fill_into(demands, *cap, &mut order, &mut a);
            if a != first {
                return Err("scratch reuse changed the allocation".into());
            }
            // conservation: contended allocations fill capacity exactly,
            // uncontended ones grant every demand
            let sum: f64 = a.iter().sum();
            let dem: f64 = demands.iter().sum();
            if dem > cap + 1e-9 {
                if (sum - cap).abs() > 1e-6 {
                    return Err(format!("contended sum {sum} != capacity {cap}"));
                }
            } else if (sum - dem).abs() > 1e-6 {
                return Err(format!("uncontended sum {sum} != demand {dem}"));
            }
            // demand cap: no task gets more than it asked for
            for (x, d) in a.iter().zip(demands) {
                if *x > d + 1e-9 || *x < -1e-12 {
                    return Err(format!("share {x} vs demand {d}"));
                }
            }
            // equal-split tail: all unmet tasks receive the same share
            let unmet: Vec<f64> = a
                .iter()
                .zip(demands)
                .filter(|(x, d)| **x < *d - 1e-9)
                .map(|(x, _)| *x)
                .collect();
            if unmet.len() >= 2 {
                let lo = unmet.iter().cloned().fold(f64::INFINITY, f64::min);
                let hi = unmet.iter().cloned().fold(0.0, f64::max);
                if hi - lo > 1e-6 {
                    return Err(format!("unmet shares unequal: {lo} vs {hi}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cached_shares_match_direct_under_mutation() {
    forall(
        "share-cache-mutation",
        30,
        |rng| rng.next_u64(),
        |&seed| {
            let mut cached = Cluster::new(ClusterConfig { seed, ..Default::default() });
            let mut direct = cached.clone();
            direct.set_share_cache_enabled(false);
            let mut rng = Rng::seeded(seed);
            let mut ids: Vec<usize> = Vec::new();
            let mut t = 0.0;
            for step in 0..50 {
                // query times are non-decreasing, like the event engine's
                t += rng.range(0.1, 30.0);
                match rng.usize(0, 3) {
                    0 => {
                        let task = Task {
                            job: step,
                            role: Role::Ps { idx: 0 },
                            server: rng.usize(0, 7),
                            cpu_demand: rng.range(0.5, 20.0),
                            bw_demand: rng.range(0.1, 8.0),
                            cpu_cap: 1.0,
                            bw_cap: 1.0,
                            cpu_throttle: rng.range(0.2, 1.0),
                            bw_throttle: 1.0,
                            active: true,
                        };
                        ids.push(cached.add_task(task.clone()));
                        direct.add_task(task);
                    }
                    1 if !ids.is_empty() => {
                        let id = *rng.choose(&ids);
                        let (c1, c2) = (rng.range(0.05, 1.0), rng.range(0.05, 1.0));
                        cached.set_caps(id, c1, c2);
                        direct.set_caps(id, c1, c2);
                    }
                    2 if !ids.is_empty() => {
                        let id = *rng.choose(&ids);
                        let (d1, d2) = (rng.range(0.5, 20.0), rng.range(0.1, 8.0));
                        cached.set_demands(id, d1, d2);
                        direct.set_demands(id, d1, d2);
                    }
                    3 if ids.len() > 1 => {
                        let id = ids.remove(rng.usize(0, ids.len() - 1));
                        cached.remove_task(id);
                        direct.remove_task(id);
                    }
                    _ => {}
                }
                for server in 0..8 {
                    for res in [Res::Cpu, Res::Bw] {
                        let x = cached.shares(server, res, t);
                        if x != direct.shares(server, res, t) {
                            return Err(format!(
                                "cached != direct at t={t} server={server} {res:?}"
                            ));
                        }
                        // a second query at the same instant is a pure
                        // cache hit and must repeat exactly
                        if cached.shares(server, res, t) != x {
                            return Err(format!("cache hit differs at t={t}"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_shares_into_bit_identical_to_shares() {
    // the slice-returning epoch APIs are pure refactors of `shares`:
    // same pairs, same order, every float bit-identical
    forall(
        "shares-into-equivalence",
        30,
        |rng| rng.next_u64(),
        |&seed| {
            let mut c = Cluster::new(ClusterConfig { seed, ..Default::default() });
            let mut rng = Rng::seeded(seed ^ 0x51AB);
            let n = rng.usize(0, 20);
            for j in 0..n {
                c.add_task(Task {
                    job: j,
                    role: Role::Ps { idx: 0 },
                    server: rng.usize(0, 7),
                    cpu_demand: rng.range(0.0, 20.0),
                    bw_demand: rng.range(0.0, 8.0),
                    cpu_cap: rng.range(0.05, 1.0),
                    bw_cap: 1.0,
                    cpu_throttle: rng.range(0.2, 1.0),
                    bw_throttle: 1.0,
                    active: true,
                });
            }
            let mut buf: Vec<(usize, f64)> = vec![(42, 4.2)]; // dirty scratch
            let mut t = 0.0;
            for _ in 0..20 {
                t += rng.range(0.1, 40.0);
                for server in 0..8 {
                    for res in [Res::Cpu, Res::Bw] {
                        let want = c.shares(server, res, t);
                        c.shares_into(server, res, t, &mut buf);
                        if want != buf {
                            return Err(format!(
                                "shares_into differs at t={t} server={server} {res:?}"
                            ));
                        }
                        let (ids, sh) = c.shares_view(server, res, t);
                        if ids.len() != sh.len()
                            || want
                                .iter()
                                .zip(ids.iter().zip(sh))
                                .any(|(&(wi, ws), (&gi, &gs))| wi != gi || ws != gs)
                            || want.len() != ids.len()
                        {
                            return Err(format!(
                                "shares_view differs at t={t} server={server} {res:?}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cluster_shares_never_exceed_capacity() {
    forall(
        "cluster-shares",
        60,
        |rng| {
            let n_tasks = rng.usize(1, 24);
            let t = rng.range(0.0, 5000.0);
            (n_tasks, t, rng.next_u64())
        },
        |&(n_tasks, t, seed)| {
            let mut c = Cluster::new(ClusterConfig { seed, ..Default::default() });
            let mut rng = Rng::seeded(seed);
            for j in 0..n_tasks {
                c.add_task(Task {
                    job: j,
                    role: Role::Ps { idx: 0 },
                    server: rng.usize(0, 7),
                    cpu_demand: rng.range(0.5, 20.0),
                    bw_demand: rng.range(0.1, 8.0),
                    cpu_cap: rng.range(0.1, 1.0),
                    bw_cap: 1.0,
                    cpu_throttle: rng.range(0.2, 1.0),
                    bw_throttle: 1.0,
                    active: true,
                });
            }
            for server in 0..8 {
                for res in [Res::Cpu, Res::Bw] {
                    let cap = match res {
                        Res::Cpu => c.server(server).cpus,
                        Res::Bw => c.server(server).bw_gbps,
                    };
                    let total: f64 = c.shares(server, res, t).iter().map(|&(_, s)| s).sum();
                    if total > cap + 1e-6 {
                        return Err(format!("server {server} {res:?}: {total} > {cap}"));
                    }
                    for (id, s) in c.shares(server, res, t) {
                        if s < 0.0 {
                            return Err(format!("negative share for task {id}: {s}"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_progress_value_bounded_and_monotone_in_updates() {
    forall(
        "progress-bounds",
        100,
        |rng| {
            let model = rng.usize(0, 9);
            let workers = rng.usize(2, 12);
            let steps = rng.usize(10, 400);
            let seed = rng.next_u64();
            (model, workers, steps, seed)
        },
        |&(model, workers, steps, seed)| {
            let spec = &star::models::ZOO[model];
            let mut p = ProgressModel::new(spec, workers);
            let mut rng = Rng::seeded(seed);
            let mut last_progress = 0.0;
            for _ in 0..steps {
                let reports = rng.usize(1, workers);
                let staleness = rng.range(0.0, 20.0);
                p.apply_update(reports, staleness, rng.chance(0.5));
                if p.progress < last_progress {
                    return Err("progress went backwards".into());
                }
                last_progress = p.progress;
                let v = p.value();
                match spec.kind {
                    star::models::Kind::Image => {
                        if !(0.0..=100.0).contains(&v) {
                            return Err(format!("accuracy {v} out of range"));
                        }
                    }
                    star::models::Kind::Nlp => {
                        if v < spec.acc_max - 1.0 || v > spec.acc0 + 1.0 {
                            return Err(format!("perplexity {v} out of range"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_comm_tree_is_acyclic_forest_with_bounded_fanout() {
    forall(
        "comm-tree",
        200,
        |rng| {
            let n = rng.usize(1, 16);
            let b = rng.usize(1, 5);
            let bw: Vec<f64> = (0..n).map(|_| rng.range(0.1, 10.0)).collect();
            (bw, b)
        },
        |(bw, b)| {
            let t = CommTree::build(bw, *b);
            for w in 0..bw.len() {
                let d = t.depth_of(w); // panics on cycle
                if d > bw.len() {
                    return Err("depth exceeds n".into());
                }
            }
            for p in 0..bw.len() {
                if t.children_of(p).len() > *b {
                    return Err(format!("fanout exceeded at {p}"));
                }
            }
            if t.root_fanin() == 0 || t.root_fanin() > *b {
                return Err(format!("root fanin {}", t.root_fanin()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_equalize_never_speeds_anyone_up() {
    forall(
        "equalize",
        200,
        |rng| {
            let n = rng.usize(1, 12);
            let times: Vec<f64> = (0..n).map(|_| rng.range(0.2, 4.0)).collect();
            let fixed: Vec<f64> = times.iter().map(|t| t * rng.range(0.05, 0.6)).collect();
            (times, fixed)
        },
        |(times, fixed)| {
            let caps = equalize_group(times, fixed);
            let t_max = times.iter().cloned().fold(0.0, f64::max);
            for (i, &c) in caps.iter().enumerate() {
                if !(0.05..=1.0).contains(&c) {
                    return Err(format!("cap {c} out of range"));
                }
                // the slowest member keeps (nearly) full resources
                if (times[i] - t_max).abs() < 1e-12 && c < 0.999 {
                    return Err("slowest member was capped".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_deprivation_never_exceeds_need_or_headroom() {
    forall(
        "deprivation",
        200,
        |rng| {
            let n = rng.usize(0, 8);
            let victims: Vec<Victim> = (0..n)
                .map(|_| Victim {
                    sensitivity: rng.range(0.01, 1.0),
                    improvement: rng.range(0.01, 1.0),
                    granted: rng.range(0.0, 10.0),
                    floor: rng.range(0.0, 5.0),
                })
                .collect();
            let need = rng.range(0.0, 20.0);
            (victims, need)
        },
        |(victims, need)| {
            let take = sensitivity_deprivation(*need, victims);
            let total: f64 = take.iter().sum();
            if total > need + 1e-6 {
                return Err(format!("took {total} > needed {need}"));
            }
            for (t, v) in take.iter().zip(victims) {
                let headroom = (v.granted - v.floor).max(0.0);
                if *t > headroom + 1e-6 || *t < -1e-9 {
                    return Err(format!("take {t} vs headroom {headroom}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_deviation_ratios_and_flags_consistent() {
    forall("deviation", 300, times_gen, |times| {
        let d = deviation_ratios(times);
        let f = straggler_flags(times);
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        for i in 0..times.len() {
            if (times[i] - min).abs() < 1e-12 && f[i] {
                return Err("fastest worker flagged".into());
            }
            if (f[i]) != (d[i] > 0.2) {
                return Err("flag/ratio mismatch".into());
            }
            if d[i] < 0.0 {
                return Err("negative deviation".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_clustering_is_ordered_partition() {
    forall("clustering", 300, times_gen, |times| {
        let clusters = cluster_times(times, 0.15, 0.02);
        let mut seen = vec![false; times.len()];
        let mut last_max = f64::NEG_INFINITY;
        for c in &clusters {
            if c.is_empty() {
                return Err("empty cluster".into());
            }
            let lo = c.iter().map(|&w| times[w]).fold(f64::INFINITY, f64::min);
            let hi = c.iter().map(|&w| times[w]).fold(f64::NEG_INFINITY, f64::max);
            if lo < last_max - 1e-12 {
                return Err("clusters overlap in time".into());
            }
            last_max = hi;
            for &w in c {
                if seen[w] {
                    return Err("worker in two clusters".into());
                }
                seen[w] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            return Err("worker missing from clustering".into());
        }
        Ok(())
    });
}
