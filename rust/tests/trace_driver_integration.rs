//! Integration: full systems over the cluster simulator — the headline
//! relationships the paper claims must hold on a reduced trace.

use star::baselines::make_policy;
use star::driver::{Driver, DriverConfig, JobStats};
use star::trace::{generate, Arch, TraceConfig};

fn run(system: &str, arch: Arch, jobs: usize) -> Vec<JobStats> {
    let trace = generate(&TraceConfig { jobs, span_s: jobs as f64 * 280.0, ..Default::default() });
    let cfg = DriverConfig { arch, record_series: false, ..Default::default() };
    let name = system.to_string();
    let (stats, _) = Driver::new(cfg, trace, Box::new(move |_| make_policy(&name).expect("known system"))).run();
    stats
}

fn mean_tta(stats: &[JobStats]) -> f64 {
    let v: Vec<f64> = stats.iter().filter_map(|s| s.tta_s).collect();
    v.iter().sum::<f64>() / v.len().max(1) as f64
}

fn mean_jct(stats: &[JobStats]) -> f64 {
    stats.iter().map(|s| s.jct_s).sum::<f64>() / stats.len().max(1) as f64
}

fn mean_acc(stats: &[JobStats]) -> f64 {
    let v: Vec<f64> = stats.iter().filter(|s| !s.is_nlp).map(|s| s.converged_value).collect();
    v.iter().sum::<f64>() / v.len().max(1) as f64
}

const JOBS: usize = 10;

#[test]
fn star_beats_ssgd_on_tta_and_jct_ps() {
    let ssgd = run("SSGD", Arch::Ps, JOBS);
    let star_h = run("STAR-H", Arch::Ps, JOBS);
    assert!(
        mean_tta(&star_h) < mean_tta(&ssgd),
        "STAR-H TTA {} !< SSGD {}",
        mean_tta(&star_h),
        mean_tta(&ssgd)
    );
    assert!(mean_jct(&star_h) < mean_jct(&ssgd));
}

#[test]
fn star_keeps_ssgd_level_accuracy() {
    let ssgd = run("SSGD", Arch::Ps, JOBS);
    let star_h = run("STAR-H", Arch::Ps, JOBS);
    assert!(
        (mean_acc(&ssgd) - mean_acc(&star_h)).abs() < 1.5,
        "accuracy gap too large: {} vs {}",
        mean_acc(&ssgd),
        mean_acc(&star_h)
    );
}

#[test]
fn asgd_family_generates_more_stragglers_than_ssgd() {
    // O5/Fig 22: switching-to-ASGD systems create more stragglers
    let ssgd = run("SSGD", Arch::Ps, JOBS);
    let sync_switch = run("Sync-Switch", Arch::Ps, JOBS);
    let s_frac = |v: &[JobStats]| {
        v.iter().map(|s| s.straggler_iters).sum::<u64>() as f64
            / v.iter().map(|s| s.iters_total).sum::<u64>().max(1) as f64
    };
    assert!(
        s_frac(&sync_switch) > s_frac(&ssgd),
        "{} !> {}",
        s_frac(&sync_switch),
        s_frac(&ssgd)
    );
}

#[test]
fn every_eval_system_completes_the_trace() {
    for arch in [Arch::Ps, Arch::AllReduce] {
        for sys in star::exp::eval::eval_systems(arch) {
            let stats = run(sys, arch, 5);
            assert_eq!(stats.len(), 5, "{sys} {arch:?}");
            for s in &stats {
                assert!(s.updates > 0, "{sys}: no updates");
                assert!(s.jct_s > 0.0);
                assert!(s.converged_value.is_finite());
            }
        }
    }
}

#[test]
fn ablations_run_and_report() {
    for (name, _) in star::star::ablations() {
        let stats = run(name, Arch::Ps, 4);
        assert_eq!(stats.len(), 4, "{name}");
    }
}

#[test]
fn star_ml_eventually_uses_its_regressor() {
    let stats = run("STAR-ML", Arch::Ps, 8);
    // ML variant must make decisions without accumulating pause time
    let pause: f64 = stats.iter().map(|s| s.decision_pause_total_s).sum();
    assert_eq!(pause, 0.0, "STAR-ML must not pause training");
    let overhead: f64 = stats.iter().map(|s| s.decision_overhead_total_s).sum();
    assert!(overhead > 0.0, "overlapped inference still accounted");
}

#[test]
fn seeds_change_outcomes_but_structure_holds() {
    let trace_a = generate(&TraceConfig { jobs: 5, span_s: 1500.0, seed: 1, ..Default::default() });
    let trace_b = generate(&TraceConfig { jobs: 5, span_s: 1500.0, seed: 2, ..Default::default() });
    let cfg = |seed| DriverConfig { seed, record_series: false, ..Default::default() };
    let (a, _) = Driver::new(cfg(1), trace_a, Box::new(|_| make_policy("SSGD").expect("known system"))).run();
    let (b, _) = Driver::new(cfg(2), trace_b, Box::new(|_| make_policy("SSGD").expect("known system"))).run();
    assert_eq!(a.len(), 5);
    assert_eq!(b.len(), 5);
    let ja: f64 = a.iter().map(|s| s.jct_s).sum();
    let jb: f64 = b.iter().map(|s| s.jct_s).sum();
    assert_ne!(ja, jb, "different seeds should differ");
}

#[test]
fn prediction_confusion_is_populated_for_star() {
    let stats = run("STAR-H", Arch::Ps, 6);
    let total: u64 = stats
        .iter()
        .map(|s| s.prediction.tp + s.prediction.fp + s.prediction.tn + s.prediction.fn_)
        .sum();
    assert!(total > 1000, "confusion counters look unpopulated: {total}");
    // prediction quality must be far better than chance on both error axes
    let fp: f64 = star::stats::mean(
        &stats.iter().map(|s| s.prediction.fp_rate()).collect::<Vec<_>>(),
    );
    let fn_: f64 = star::stats::mean(
        &stats.iter().map(|s| s.prediction.fn_rate()).collect::<Vec<_>>(),
    );
    assert!(fp < 0.5, "fp {fp}");
    assert!(fn_ < 0.6, "fn {fn_}");
}

// ---------------------------------------------------------------------------
// Fault injection (resilience subsystem)
// ---------------------------------------------------------------------------

fn run_faulted(system: &str, arch: Arch, jobs: usize, rate: f64) -> Vec<JobStats> {
    let trace = generate(&TraceConfig { jobs, span_s: jobs as f64 * 280.0, ..Default::default() });
    let faults = star::faults::plan_at_rate(
        rate,
        0,
        &trace,
        star::faults::span_for(&trace, 20_000.0),
        8,
    );
    let cfg = DriverConfig {
        arch,
        record_series: false,
        faults,
        // heavy failure rates can keep a job from ever converging; bound
        // the run instead of riding the 40 000 s duration cap
        max_job_duration_s: 15_000.0,
        max_updates_per_job: 30_000,
        max_iters_per_job: 50_000,
        ..Default::default()
    };
    let name = system.to_string();
    let (stats, _) =
        Driver::new(cfg, trace, Box::new(move |_| make_policy(&name).expect("known system"))).run();
    stats
}

#[test]
fn every_eval_system_survives_injected_failures() {
    // worker crashes, PS rollbacks, server outages and degradation
    // windows on both architectures: every policy must still complete
    // every job without scheduling dead workers
    for arch in [Arch::Ps, Arch::AllReduce] {
        for sys in star::exp::eval::eval_systems(arch) {
            let stats = run_faulted(sys, arch, 4, 4.0);
            assert_eq!(stats.len(), 4, "{sys} {arch:?}");
            for s in &stats {
                assert!(s.updates > 0, "{sys} {arch:?}: no updates under faults");
                assert!(s.converged_value.is_finite(), "{sys} {arch:?}");
                assert!(s.downtime_s >= 0.0 && s.downtime_s.is_finite());
            }
        }
    }
}

#[test]
fn faults_increase_ssgd_tta_on_the_same_trace() {
    let clean = run("SSGD", Arch::Ps, 4);
    let faulted = run_faulted("SSGD", Arch::Ps, 4, 6.0);
    let score = |v: &[JobStats]| -> f64 {
        v.iter().map(|s| s.tta_s.unwrap_or(s.jct_s)).sum::<f64>()
    };
    assert!(
        score(&faulted) > score(&clean),
        "injected failures must cost SSGD time: {} !> {}",
        score(&faulted),
        score(&clean)
    );
    let touched: f64 = faulted.iter().map(|s| s.downtime_s).sum();
    let rollbacks: u64 = faulted.iter().map(|s| s.rollbacks).sum();
    assert!(touched > 0.0 || rollbacks > 0, "plan must actually bite");
}
