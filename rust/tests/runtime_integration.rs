//! Integration: the full AOT bridge — load HLO-text artifacts built by
//! `make artifacts`, run them through PJRT, and verify training semantics
//! (loss decreases, kernels match the pure-Rust oracle).
//!
//! All tests skip gracefully when artifacts are missing.

use star::runtime::{LstmPredictor, Manifest, Runtime, TrainSession};
use star::simrng::Rng;

fn setup() -> Option<(Runtime, Manifest)> {
    let man = match Manifest::discover() {
        Ok(m) => m,
        Err(_) => {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
    };
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            // artifacts exist but the binary lacks the `xla` feature
            eprintln!("skipping: {e}");
            return None;
        }
    };
    Some((rt, man))
}

fn synth_tokens(info: &star::runtime::ConfigInfo, rng: &mut Rng) -> Vec<i32> {
    // zipf-distributed synthetic corpus (matches examples/e2e_train.rs)
    (0..info.batch * (info.seq_len + 1))
        .map(|_| rng.zipf(info.vocab, 1.1) as i32)
        .collect()
}

#[test]
fn manifest_lists_tiny_config() {
    let Some((_rt, man)) = setup() else { return };
    let names = man.config_names();
    assert!(names.iter().any(|n| n == "tiny"), "{names:?}");
    let info = man.config("tiny").unwrap();
    assert!(info.param_count > 0);
    assert_eq!(info.padded_param_count % 4096, 0);
    assert!(info.use_pallas_matmul, "tiny config exercises the Pallas path");
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    let Some((rt, man)) = setup() else { return };
    let mut s1 = TrainSession::new(&rt, &man, "tiny").unwrap();
    let mut s2 = TrainSession::new(&rt, &man, "tiny").unwrap();
    s1.init_params(7).unwrap();
    s2.init_params(7).unwrap();
    assert_eq!(s1.params, s2.params);
    s2.init_params(8).unwrap();
    assert_ne!(s1.params, s2.params);
    // finite and reasonably scaled
    assert!(s1.params.iter().all(|x| x.is_finite()));
    let norm: f32 = s1.params.iter().map(|x| x * x).sum::<f32>().sqrt();
    assert!(norm > 1.0 && norm < 1e4, "norm={norm}");
}

#[test]
fn train_step_loss_near_uniform_and_grads_nonzero() {
    let Some((rt, man)) = setup() else { return };
    let mut s = TrainSession::new(&rt, &man, "tiny").unwrap();
    s.init_params(0).unwrap();
    let mut rng = Rng::seeded(1);
    let toks = synth_tokens(&s.info, &mut rng);
    let (loss, grads) = s.train_step(&toks).unwrap();
    let expect = (s.info.vocab as f32).ln();
    // zipf-skewed targets + logit variance at init put loss a bit above
    // ln(V); just require the right ballpark
    assert!((loss - expect).abs() < 2.5, "loss {loss} vs ln(V) {expect}");
    let gnorm: f32 = grads.iter().map(|g| g * g).sum::<f32>().sqrt();
    assert!(gnorm > 1e-3, "gradient is zero?");
    // padding region receives zero gradient
    let pc = s.info.param_count;
    assert!(grads[pc..].iter().all(|&g| g == 0.0));
}

#[test]
fn pjrt_grad_acc_and_apply_match_pure_rust_oracle() {
    let Some((rt, man)) = setup() else { return };
    let mut s = TrainSession::new(&rt, &man, "tiny").unwrap();
    s.init_params(3).unwrap();
    let p = s.info.padded_param_count;
    let mut rng = Rng::seeded(9);
    let g1: Vec<f32> = (0..p).map(|_| rng.normal() as f32 * 0.1).collect();
    let g2: Vec<f32> = (0..p).map(|_| rng.normal() as f32 * 0.1).collect();

    // PJRT path (Pallas kernels)
    let acc0 = vec![0.0f32; p];
    let acc1 = s.grad_acc(&acc0, &g1, 1.0).unwrap();
    let acc2 = s.grad_acc(&acc1, &g2, 1.0).unwrap();

    // pure-Rust oracle
    let mut want = vec![0.0f32; p];
    star::agg::accumulate(&mut want, &g1, 1.0);
    star::agg::accumulate(&mut want, &g2, 1.0);
    for (a, b) in acc2.iter().zip(&want) {
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }

    // fused apply
    let before = s.params.clone();
    s.apply_update(&acc2, 0.05).unwrap();
    let mut want_p = before.clone();
    star::agg::sgd_apply(&mut want_p, &want, 0.05);
    for (a, b) in s.params.iter().zip(&want_p) {
        assert!((a - b).abs() < 1e-5);
    }
}

#[test]
fn sgd_loop_decreases_loss_through_pjrt() {
    let Some((rt, man)) = setup() else { return };
    let mut s = TrainSession::new(&rt, &man, "tiny").unwrap();
    s.init_params(0).unwrap();
    let mut rng = Rng::seeded(2);
    let toks = synth_tokens(&s.info, &mut rng);
    let (loss0, _) = s.train_step(&toks).unwrap();
    let mut last = loss0;
    for _ in 0..4 {
        let (_, grads) = s.train_step(&toks).unwrap();
        s.xorder_update(&[grads], 0.5).unwrap();
        let (l, _) = s.train_step(&toks).unwrap();
        last = l;
    }
    assert!(last < loss0 - 0.05, "loss {loss0} -> {last}");
}

#[test]
fn xorder_update_equals_mean_gradient_update() {
    let Some((rt, man)) = setup() else { return };
    let mut a = TrainSession::new(&rt, &man, "tiny").unwrap();
    let mut b = TrainSession::new(&rt, &man, "tiny").unwrap();
    a.init_params(5).unwrap();
    b.init_params(5).unwrap();
    let mut rng = Rng::seeded(3);
    let t1 = synth_tokens(&a.info, &mut rng);
    let t2 = synth_tokens(&a.info, &mut rng);
    let (_, g1) = a.train_step(&t1).unwrap();
    let (_, g2) = a.train_step(&t2).unwrap();

    // x-order path: accumulate then apply lr/x
    a.xorder_update(&[g1.clone(), g2.clone()], 0.1).unwrap();

    // manual mean path
    let p = b.info.padded_param_count;
    let mut mean = vec![0.0f32; p];
    star::agg::mean_naive(&[&g1, &g2], &mut mean);
    star::agg::sgd_apply(&mut b.params, &mean, 0.1);

    for (x, y) in a.params.iter().zip(&b.params) {
        assert!((x - y).abs() < 1e-4, "{x} vs {y}");
    }
}

#[test]
fn lstm_predictor_artifact_runs() {
    let Some((rt, man)) = setup() else { return };
    let p = LstmPredictor::new(&rt, &man).expect("predictor artifact");
    // constant history => prediction close to the constant (residual head)
    let rows: Vec<[f32; 2]> = (0..32).map(|_| [0.6f32, 0.4f32]).collect();
    let (cpu, bw) = p.predict_rows(&rows).unwrap();
    assert!((cpu - 0.6).abs() < 0.15, "cpu={cpu}");
    assert!((bw - 0.4).abs() < 0.15, "bw={bw}");
    // via the ResourcePredictor trait with a short (padded) history
    let mut h = star::predict::History::new();
    h.push(0.5, 0.5, 0.1);
    h.push(0.52, 0.48, 0.1);
    let mut lp = p;
    let (c2, b2) = star::predict::ResourcePredictor::predict(&mut lp, &h);
    assert!((0.0..=1.0).contains(&c2) && (0.0..=1.0).contains(&b2));
}

#[test]
fn small_config_also_loads() {
    let Some((rt, man)) = setup() else { return };
    if !man.config_names().iter().any(|n| n == "small") {
        return;
    }
    let mut s = TrainSession::new(&rt, &man, "small").unwrap();
    s.init_params(0).unwrap();
    let mut rng = Rng::seeded(4);
    let toks = synth_tokens(&s.info, &mut rng);
    let (loss, _) = s.train_step(&toks).unwrap();
    assert!(loss.is_finite());
}
