//! Contracts of the scenario-space sampler (DESIGN.md §11):
//!
//! * every scenario sampled from every builtin space validates and
//!   round-trips parse→emit→parse byte-identically (the property the
//!   fabric's pure `(space, index)` cells lean on);
//! * sampling is a pure function of `(space, seed, index)` — repeating
//!   a batch, or drawing index k alone, reproduces the same bytes;
//! * the space specs themselves round-trip canonically.

use star::jsonio::Json;
use star::scenario::{builtin_spaces, ScenarioSpace};
use star::testutil::forall;

/// Canonical bytes of a sampled scenario — what `scenario sample`
/// writes and what the determinism contract is stated over.
fn sample_bytes(space: &ScenarioSpace, index: usize) -> String {
    space.sample_at(index).to_json().to_string_pretty()
}

#[test]
fn every_builtin_sample_validates_and_round_trips() {
    for space in builtin_spaces() {
        forall(
            &format!("space-{}-samples", space.name),
            40,
            // exercise a wide index range, not just the first few
            |rng| rng.usize(0, 5000),
            |&index| {
                let sc = space.sample_at(index);
                sc.validate().map_err(|e| {
                    format!("sample {index} of {:?} fails validate: {e:#}", space.name)
                })?;
                let emitted = sc.to_json().to_string_pretty();
                let back = star::scenario::Scenario::from_json(&Json::parse(&emitted).unwrap())
                    .map_err(|e| format!("sample {index} does not re-parse: {e:#}"))?;
                let again = back.to_json().to_string_pretty();
                if emitted != again {
                    return Err(format!(
                        "sample {index} of {:?} is not canonical under parse→emit→parse",
                        space.name
                    ));
                }
                Ok(())
            },
        );
    }
}

#[test]
fn sampling_twice_and_sampling_alone_are_byte_identical() {
    for space in builtin_spaces() {
        // a batch drawn twice
        let first: Vec<String> = (0..12).map(|k| sample_bytes(&space, k)).collect();
        let second: Vec<String> = (0..12).map(|k| sample_bytes(&space, k)).collect();
        assert_eq!(first, second, "space {:?} must sample deterministically", space.name);
        // index k drawn alone (reverse order, so no sequential state
        // could fake it) equals its batch position
        for k in (0..12).rev() {
            assert_eq!(
                sample_bytes(&space, k),
                first[k],
                "space {:?} sample {k} must be pure in (seed, index)",
                space.name
            );
        }
    }
}

#[test]
fn builtin_space_specs_round_trip_canonically() {
    for space in builtin_spaces() {
        space.validate().unwrap_or_else(|e| panic!("builtin space {:?}: {e:#}", space.name));
        let emitted = space.to_json().to_string_pretty();
        let back = ScenarioSpace::from_json(&Json::parse(&emitted).unwrap()).unwrap();
        assert_eq!(
            back.to_json().to_string_pretty(),
            emitted,
            "space {:?} must be canonical under parse→emit→parse",
            space.name
        );
        assert_eq!(back.sample_at(3).to_json(), space.sample_at(3).to_json());
    }
}

#[test]
fn distinct_indexes_explore_the_space() {
    // not a tautology test: with free dims present, consecutive samples
    // must not collapse onto one point (the RNG fork actually varies)
    for space in builtin_spaces() {
        let distinct: std::collections::BTreeSet<String> =
            (0..8).map(|k| sample_bytes(&space, k)).collect();
        assert!(
            distinct.len() > 1,
            "space {:?} has free dims but 8 samples were all identical",
            space.name
        );
    }
}
