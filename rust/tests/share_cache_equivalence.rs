//! The share-cache contract: the epoch cache is a pure performance
//! optimization — replaying a full trace with the cache enabled must be
//! **bit-identical** to replaying it with every share query recomputed
//! from scratch. This holds because (a) all share-relevant mutation goes
//! through generation-bumping setters, (b) the contention streams are
//! extended lazily but deterministically per (server/task) RNG, and
//! (c) pruning only drops entries that cannot influence the driver's
//! non-decreasing query times.

use star::baselines::make_policy;
use star::driver::{Driver, DriverConfig, JobStats, ServerRecord};
use star::trace::{generate, Arch, TraceConfig};

fn run(arch: Arch, system: &str, cache: bool) -> (Vec<JobStats>, Vec<ServerRecord>) {
    let trace = generate(&TraceConfig { jobs: 8, span_s: 2000.0, ..Default::default() });
    let cfg = DriverConfig {
        arch,
        record_series: true,
        server_sample_period_s: 200.0,
        ..Default::default()
    };
    let name = system.to_string();
    let mut driver = Driver::new(cfg, trace, Box::new(move |_| make_policy(&name).expect("known system")));
    driver.cluster.set_share_cache_enabled(cache);
    driver.run()
}

/// Every field compared with exact equality — "close" is not good enough:
/// the cache must not perturb a single RNG draw or float operation.
fn assert_bit_identical(a: &[JobStats], b: &[JobStats]) {
    assert_eq!(a.len(), b.len(), "job count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.job, y.job);
        assert_eq!(x.system, y.system);
        assert_eq!(x.start_s, y.start_s, "job {}", x.job);
        assert_eq!(x.end_s, y.end_s, "job {}", x.job);
        assert_eq!(x.tta_s, y.tta_s, "job {} TTA", x.job);
        assert_eq!(x.jct_s, y.jct_s, "job {} JCT", x.job);
        assert_eq!(x.converged_value, y.converged_value, "job {}", x.job);
        assert_eq!(x.updates, y.updates, "job {}", x.job);
        assert_eq!(x.iters_total, y.iters_total, "job {}", x.job);
        assert_eq!(x.straggler_iters, y.straggler_iters, "job {}", x.job);
        assert_eq!(x.straggler_episodes, y.straggler_episodes, "job {}", x.job);
        assert_eq!(x.mode_switches, y.mode_switches, "job {}", x.job);
        assert_eq!(x.decision_count, y.decision_count, "job {}", x.job);
        assert_eq!(x.prediction.tp, y.prediction.tp, "job {}", x.job);
        assert_eq!(x.prediction.fp, y.prediction.fp, "job {}", x.job);
        assert_eq!(x.prediction.tn, y.prediction.tn, "job {}", x.job);
        assert_eq!(x.prediction.fn_, y.prediction.fn_, "job {}", x.job);
        assert_eq!(x.decision_pause_total_s, y.decision_pause_total_s, "job {}", x.job);
        assert_eq!(x.value_series, y.value_series, "job {}", x.job);
        // per-iteration breakdowns: the rawest observable of the share path
        assert_eq!(x.series.len(), y.series.len());
        for (sw, dw) in x.series.iter().zip(&y.series) {
            assert_eq!(sw.len(), dw.len(), "job {} series length", x.job);
            for (si, di) in sw.iter().zip(dw) {
                assert_eq!(si.pre_s, di.pre_s, "job {}", x.job);
                assert_eq!(si.gpu_s, di.gpu_s, "job {}", x.job);
                assert_eq!(si.comm_s, di.comm_s, "job {}", x.job);
                assert_eq!(si.total_s, di.total_s, "job {}", x.job);
                assert_eq!(si.cpu_share, di.cpu_share, "job {}", x.job);
                assert_eq!(si.bw_share, di.bw_share, "job {}", x.job);
            }
        }
    }
}

fn assert_records_identical(a: &[ServerRecord], b: &[ServerRecord]) {
    assert_eq!(a.len(), b.len(), "record count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.time, y.time);
        assert_eq!(x.server, y.server);
        assert_eq!(x.ps_hosted, y.ps_hosted);
        assert_eq!(x.cpu_util, y.cpu_util, "server {} t {}", x.server, x.time);
        assert_eq!(x.bw_util, y.bw_util, "server {} t {}", x.server, x.time);
    }
}

#[test]
fn cached_replay_is_bit_identical_ps() {
    let (cached, cached_recs) = run(Arch::Ps, "STAR-H", true);
    let (direct, direct_recs) = run(Arch::Ps, "STAR-H", false);
    assert_bit_identical(&cached, &direct);
    assert_records_identical(&cached_recs, &direct_recs);
}

#[test]
fn cached_replay_is_bit_identical_ar() {
    let (cached, cached_recs) = run(Arch::AllReduce, "STAR-H", true);
    let (direct, direct_recs) = run(Arch::AllReduce, "STAR-H", false);
    assert_bit_identical(&cached, &direct);
    assert_records_identical(&cached_recs, &direct_recs);
}

#[test]
fn cached_replay_is_bit_identical_for_deprivation_free_baseline() {
    // SSGD exercises the plain SSGD round-start burst (many same-instant
    // queries, the cache's sweet spot) without STAR's cap churn
    let (cached, _) = run(Arch::Ps, "SSGD", true);
    let (direct, _) = run(Arch::Ps, "SSGD", false);
    assert_bit_identical(&cached, &direct);
}
