//! The datacenter-scale partitioning contracts (DESIGN.md §12): the
//! sharded event queue, the per-server share-epoch partitions, and the
//! streaming stats path are pure performance changes — each must be
//! **equivalent** to its serial/accumulating reference:
//!
//! - the job-partitioned `EventQueue` pops in byte-identical order at
//!   any shard count (the `(at, seq)` total order is shard-independent);
//! - a scaled multi-shard, multi-partition replay with the share cache
//!   on is bit-identical to the same replay with every share recomputed
//!   from scratch (the partitioned generations never serve a stale
//!   epoch);
//! - streaming stats (fold-on-finish) match accumulate-then-summarize.

use star::baselines::make_policy;
use star::driver::{Driver, DriverConfig, Event, EventQueue, JobStats, StatStream, StreamAgg};
use star::simrng::Rng;
use star::trace::{generate, Arch, TraceConfig};

/// Comparable key for a popped event (Event carries no derives — the
/// driver never compares events, only this test does).
fn key(e: &Event) -> (u8, usize, usize, u64) {
    match *e {
        Event::Arrive(job) => (0, job, 0, 0),
        Event::WorkerDone { job, worker, iter } => (1, job, worker, iter),
        Event::ArFlush { job } => (2, job, 0, 0),
        Event::ServerSample => (3, 0, 0, 0),
        Event::Fault(i) => (4, i, 0, 0),
        Event::WorkerRestart { job, worker } => (5, job, worker, 0),
        Event::PsRestart { job, ps_idx } => (6, job, ps_idx, 0),
    }
}

/// Rebuild an event from its key (events are plain data — one draw is
/// replayed identically into every queue under comparison).
fn event_from(k: (u8, usize, usize, u64)) -> Event {
    match k.0 {
        0 => Event::Arrive(k.1),
        1 => Event::WorkerDone { job: k.1, worker: k.2, iter: k.3 },
        2 => Event::ArFlush { job: k.1 },
        3 => Event::ServerSample,
        4 => Event::Fault(k.1),
        5 => Event::WorkerRestart { job: k.1, worker: k.2 },
        _ => Event::PsRestart { job: k.1, ps_idx: k.2 },
    }
}

fn random_event_key(rng: &mut Rng) -> (u8, usize, usize, u64) {
    let job = rng.usize(0, 999);
    match rng.usize(0, 6) {
        0 => (0, job, 0, 0),
        1 => (1, job, rng.usize(0, 15), rng.usize(0, 40) as u64),
        2 => (2, job, 0, 0),
        3 => (3, 0, 0, 0),
        4 => (4, rng.usize(0, 99), 0, 0),
        5 => (5, job, rng.usize(0, 15), 0),
        _ => (6, job, rng.usize(0, 7), 0),
    }
}

/// Random interleavings of schedules and pops must pop identically
/// across 1/2/8 partitions — the queue-level half of the golden-trace
/// guarantee (the sim-level proptest covers the generic engine).
#[test]
fn event_queue_pop_order_identical_across_shard_counts() {
    for case in 0..30u64 {
        let mut rng = Rng::seeded(0x9A27_1D00 + case);
        let mut queues = [EventQueue::new(1), EventQueue::new(2), EventQueue::new(8)];
        assert_eq!(queues[0].num_shards(), 1);
        assert_eq!(queues[1].num_shards(), 2);
        assert_eq!(queues[2].num_shards(), 8);
        for _ in 0..rng.usize(50, 300) {
            if rng.chance(0.6) {
                // same-instant bursts are the FIFO-tie-break stressor
                let at = if rng.chance(0.3) { 100.0 } else { rng.range(0.0, 5_000.0) };
                let k = random_event_key(&mut rng);
                for q in queues.iter_mut() {
                    q.schedule_at(at, event_from(k));
                }
            } else {
                let pops: Vec<Option<(u64, (u8, usize, usize, u64))>> = queues
                    .iter_mut()
                    .map(|q| q.next().map(|(t, e)| (t.to_bits(), key(&e))))
                    .collect();
                assert_eq!(pops[0], pops[1], "case {case}: 1 vs 2 shards");
                assert_eq!(pops[0], pops[2], "case {case}: 1 vs 8 shards");
            }
        }
        // drain: the tails must agree too
        loop {
            let pops: Vec<Option<(u64, (u8, usize, usize, u64))>> = queues
                .iter_mut()
                .map(|q| q.next().map(|(t, e)| (t.to_bits(), key(&e))))
                .collect();
            assert_eq!(pops[0], pops[1], "case {case}: drain 1 vs 2");
            assert_eq!(pops[0], pops[2], "case {case}: drain 1 vs 8");
            if pops[0].is_none() {
                break;
            }
        }
        assert_eq!(queues[0].events_processed(), queues[2].events_processed());
        assert_eq!(queues[0].now().to_bits(), queues[2].now().to_bits());
    }
}

fn scaled_cfg(arch: Arch, streaming: bool) -> DriverConfig {
    // 2× the paper testbed: 16 servers → a 2-shard EventQueue and 16
    // epoch partitions, so both partitioned structures are genuinely
    // exercised (the paper cluster collapses to one shard)
    let cluster = star::cluster::ClusterConfig {
        gpu_servers: 10,
        cpu_servers: 6,
        ..Default::default()
    };
    let mut cfg = DriverConfig {
        arch,
        cluster,
        record_series: false,
        streaming_stats: streaming,
        ..Default::default()
    };
    let trace = generate(&TraceConfig::paced_scaled(10, 3, 2));
    cfg.faults = star::scenario::FaultRegime::Rate { rate: 1.0, seed: 9 }.plan(
        &trace,
        star::faults::span_for(&trace, cfg.max_job_duration_s),
        cfg.cluster.total_servers(),
    );
    cfg
}

fn scaled_driver(arch: Arch, streaming: bool) -> Driver {
    let cfg = scaled_cfg(arch, streaming);
    let trace = generate(&TraceConfig::paced_scaled(10, 3, 2));
    Driver::new(cfg, trace, Box::new(|_| make_policy("STAR-H").expect("known system")))
}

fn assert_stats_identical(a: &[JobStats], b: &[JobStats]) {
    assert_eq!(a.len(), b.len(), "job count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.job, y.job);
        assert_eq!(x.start_s, y.start_s, "job {}", x.job);
        assert_eq!(x.end_s, y.end_s, "job {}", x.job);
        assert_eq!(x.jct_s, y.jct_s, "job {}", x.job);
        assert_eq!(x.tta_s, y.tta_s, "job {}", x.job);
        assert_eq!(x.updates, y.updates, "job {}", x.job);
        assert_eq!(x.iters_total, y.iters_total, "job {}", x.job);
        assert_eq!(x.straggler_iters, y.straggler_iters, "job {}", x.job);
        assert_eq!(x.downtime_s, y.downtime_s, "job {}", x.job);
    }
}

/// Partitioned epochs on a multi-shard cluster: cache on vs cache off
/// (every query recomputed) must be bit-identical — a stale partition
/// would perturb an iteration time and cascade into every field.
#[test]
fn scaled_cluster_cached_replay_is_bit_identical() {
    for arch in [Arch::Ps, Arch::AllReduce] {
        let mut cached = scaled_driver(arch, false);
        cached.cluster.set_share_cache_enabled(true);
        let mut direct = scaled_driver(arch, false);
        direct.cluster.set_share_cache_enabled(false);
        let (a, _) = cached.run();
        let (b, _) = direct.run();
        assert!(!a.is_empty(), "scaled replay must finish jobs");
        assert_stats_identical(&a, &b);
    }
}

fn assert_streams_match(name: &str, a: &StatStream, b: &StatStream) {
    assert_eq!(a.count, b.count, "{name} count");
    assert!((a.sum - b.sum).abs() <= 1e-9, "{name} sum: {} vs {}", a.sum, b.sum);
    assert!((a.mean() - b.mean()).abs() <= 1e-9, "{name} mean");
    for q in [0.01, 0.5, 0.99] {
        let (x, y) = (a.quantile(q), b.quantile(q));
        assert!((x - y).abs() <= 1e-9, "{name} q{q}: {x} vs {y}");
    }
}

/// `--streaming-stats` folds each job at termination; the reference
/// accumulates every JobStats and summarizes at the end. Same trace,
/// same fold order ⇒ the aggregates must agree (to 1e-9; the counters
/// exactly).
#[test]
fn streaming_stats_match_accumulate_then_summarize() {
    for arch in [Arch::Ps, Arch::AllReduce] {
        let (stats, _, accum_metrics) = scaled_driver(arch, false).run_instrumented();
        let reference = StreamAgg::from_stats(&stats);
        let (streamed, _, stream_metrics) = scaled_driver(arch, true).run_streaming();
        assert_eq!(reference.jobs, streamed.jobs);
        assert_eq!(stats.len() as u64, stream_metrics.jobs_finished);
        assert_eq!(accum_metrics.jobs_finished, stream_metrics.jobs_finished);
        // the streaming run must not perturb the simulation itself
        assert_eq!(accum_metrics.events, stream_metrics.events);
        assert_streams_match("jct_s", &reference.jct_s, &streamed.jct_s);
        assert_streams_match("tta_s", &reference.tta_s, &streamed.tta_s);
        assert_streams_match("queue_s", &reference.queue_s, &streamed.queue_s);
        assert_streams_match("updates", &reference.updates, &streamed.updates);
        assert_streams_match("iters", &reference.iters, &streamed.iters);
        assert_streams_match("downtime_s", &reference.downtime_s, &streamed.downtime_s);
        assert_eq!(reference.straggler_iters, streamed.straggler_iters);
        assert_eq!(reference.rollbacks, streamed.rollbacks);
    }
}
