//! Every shipped example scenario must parse, validate, round-trip
//! through the canonical JSON emission, and smoke-run end to end — the
//! same contract the CI scenario step enforces in release mode.
//! Files whose stem starts with `space_` are scenario *spaces*
//! (DESIGN.md §11) and get the space contract instead: parse,
//! round-trip, and sample into valid scenarios.

use std::path::{Path, PathBuf};

use star::jsonio::Json;
use star::scenario::{self, RunOpts, Scenario, ScenarioSpace};

fn examples_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/scenarios")
}

fn is_space(path: &Path) -> bool {
    path.file_stem().map(|s| s.to_string_lossy().starts_with("space_")).unwrap_or(false)
}

fn all_json_files() -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = std::fs::read_dir(examples_dir())
        .expect("examples/scenarios must exist")
        .map(|e| e.expect("readable dir entry").path())
        .filter(|p| p.extension().map(|e| e == "json").unwrap_or(false))
        .collect();
    out.sort();
    out
}

fn example_files() -> Vec<PathBuf> {
    all_json_files().into_iter().filter(|p| !is_space(p)).collect()
}

fn space_files() -> Vec<PathBuf> {
    all_json_files().into_iter().filter(|p| is_space(p)).collect()
}

#[test]
fn ships_at_least_three_example_scenarios() {
    let files = example_files();
    assert!(files.len() >= 3, "expected >= 3 example scenarios, found {files:?}");
    let names: Vec<String> = files
        .iter()
        .map(|p| p.file_stem().unwrap().to_string_lossy().into_owned())
        .collect();
    for expected in ["philly_default", "fault_storm", "oversubscribed_cpu"] {
        assert!(names.contains(&expected.to_string()), "missing {expected}: {names:?}");
    }
}

#[test]
fn every_example_parses_and_round_trips() {
    for path in example_files() {
        let sc = Scenario::from_file(&path)
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        // the file name matches the spec's name (artifacts stay traceable)
        assert_eq!(
            sc.name,
            path.file_stem().unwrap().to_string_lossy(),
            "{}: file name and scenario.name must agree",
            path.display()
        );
        // parse -> emit -> parse -> emit is the identity
        let j = sc.to_json();
        let again = Scenario::from_json(&Json::parse(&j.to_string_pretty()).unwrap())
            .unwrap_or_else(|e| panic!("{}: re-parse of emission: {e:#}", path.display()));
        assert_eq!(j, again.to_json(), "{}: emission is not canonical", path.display());
    }
}

#[test]
fn every_example_smoke_runs() {
    for path in example_files() {
        let sc = Scenario::from_file(&path)
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        let opts = RunOpts {
            quick: true,
            jobs_override: Some(2),
            threads: 1,
            out_dir: std::env::temp_dir()
                .join("star_scenario_examples")
                .join(sc.name.clone()),
        };
        scenario::run(&sc, &opts)
            .unwrap_or_else(|e| panic!("{}: smoke run failed: {e:#}", path.display()));
        // generic scenarios leave a parseable artifact behind
        if sc.experiments.is_empty() {
            let artifact = opts.out_dir.join(format!("scenario_{}.json", sc.name));
            let doc = Json::parse_file(&artifact)
                .unwrap_or_else(|e| panic!("{}: artifact: {e:#}", path.display()));
            assert_eq!(doc.get("schema").unwrap().str().unwrap(), "star-bench-v1");
            let cells = doc.get("results").unwrap().arr().unwrap().len();
            assert!(cells > 0, "{}: artifact has no result cells", path.display());
        }
    }
}

#[test]
fn example_spaces_parse_round_trip_and_sample_valid_scenarios() {
    let files = space_files();
    assert!(!files.is_empty(), "expected at least one space_*.json example");
    for path in files {
        let sp = ScenarioSpace::from_file(&path)
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        assert_eq!(
            sp.name,
            path.file_stem().unwrap().to_string_lossy(),
            "{}: file name and space.name must agree",
            path.display()
        );
        // parse -> emit -> parse -> emit is the identity
        let j = sp.to_json();
        let again = ScenarioSpace::from_json(&Json::parse(&j.to_string_pretty()).unwrap())
            .unwrap_or_else(|e| panic!("{}: re-parse of emission: {e:#}", path.display()));
        assert_eq!(j, again.to_json(), "{}: emission is not canonical", path.display());
        // the file must describe a real search: at least one free dim
        assert!(
            !sp.free_dims().is_empty(),
            "{}: a space example should vary something",
            path.display()
        );
        // sampled scenarios validate and are deterministic per index
        for k in [0, 1, 7] {
            let sc = sp.sample_at(k);
            sc.validate().unwrap_or_else(|e| panic!("{}: sample {k}: {e:#}", path.display()));
            assert_eq!(sc.to_json(), sp.sample_at(k).to_json(), "sample {k} must be pure");
        }
    }
}
