//! Every shipped example scenario must parse, validate, round-trip
//! through the canonical JSON emission, and smoke-run end to end — the
//! same contract the CI scenario step enforces in release mode.

use std::path::PathBuf;

use star::jsonio::Json;
use star::scenario::{self, RunOpts, Scenario};

fn examples_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/scenarios")
}

fn example_files() -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = std::fs::read_dir(examples_dir())
        .expect("examples/scenarios must exist")
        .map(|e| e.expect("readable dir entry").path())
        .filter(|p| p.extension().map(|e| e == "json").unwrap_or(false))
        .collect();
    out.sort();
    out
}

#[test]
fn ships_at_least_three_example_scenarios() {
    let files = example_files();
    assert!(files.len() >= 3, "expected >= 3 example scenarios, found {files:?}");
    let names: Vec<String> = files
        .iter()
        .map(|p| p.file_stem().unwrap().to_string_lossy().into_owned())
        .collect();
    for expected in ["philly_default", "fault_storm", "oversubscribed_cpu"] {
        assert!(names.contains(&expected.to_string()), "missing {expected}: {names:?}");
    }
}

#[test]
fn every_example_parses_and_round_trips() {
    for path in example_files() {
        let sc = Scenario::from_file(&path)
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        // the file name matches the spec's name (artifacts stay traceable)
        assert_eq!(
            sc.name,
            path.file_stem().unwrap().to_string_lossy(),
            "{}: file name and scenario.name must agree",
            path.display()
        );
        // parse -> emit -> parse -> emit is the identity
        let j = sc.to_json();
        let again = Scenario::from_json(&Json::parse(&j.to_string_pretty()).unwrap())
            .unwrap_or_else(|e| panic!("{}: re-parse of emission: {e:#}", path.display()));
        assert_eq!(j, again.to_json(), "{}: emission is not canonical", path.display());
    }
}

#[test]
fn every_example_smoke_runs() {
    for path in example_files() {
        let sc = Scenario::from_file(&path)
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        let opts = RunOpts {
            quick: true,
            jobs_override: Some(2),
            threads: 1,
            out_dir: std::env::temp_dir()
                .join("star_scenario_examples")
                .join(sc.name.clone()),
        };
        scenario::run(&sc, &opts)
            .unwrap_or_else(|e| panic!("{}: smoke run failed: {e:#}", path.display()));
        // generic scenarios leave a parseable artifact behind
        if sc.experiments.is_empty() {
            let artifact = opts.out_dir.join(format!("scenario_{}.json", sc.name));
            let doc = Json::parse_file(&artifact)
                .unwrap_or_else(|e| panic!("{}: artifact: {e:#}", path.display()));
            assert_eq!(doc.get("schema").unwrap().str().unwrap(), "star-bench-v1");
            let cells = doc.get("results").unwrap().arr().unwrap().len();
            assert!(cells > 0, "{}: artifact has no result cells", path.display());
        }
    }
}
