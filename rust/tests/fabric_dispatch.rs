//! End-to-end contracts of the sweep fabric (`star dispatch` + `star
//! worker`): dispatched artifacts are byte-identical to a serial
//! in-process run, an interrupted dispatch resumes from its journal
//! re-running only the missing cells, and seeded chaos (worker kills,
//! stalls) changes nothing but the wall clock.

use std::io::BufRead;
use std::path::{Path, PathBuf};

use star::exp::{resilience, ExpCtx};
use star::fabric::chaos::ChaosConfig;
use star::fabric::dispatch::{dispatch, DispatchOpts, DispatchReport};
use star::fabric::journal::Journal;
use star::fabric::protocol::CellDone;
use star::fabric::SweepSpec;
use star::jsonio::Json;
use star::scenario::search::{self, SearchOpts};
use star::scenario::{self, find_space, RunOpts, Scenario};
use star::trace::Arch;

const JOBS: usize = 2;
/// quick resilience grid: 3 rates x 3 systems
const CELLS: usize = 9;

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("star_fabric_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn worker_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_star"))
}

fn read(path: &Path) -> String {
    std::fs::read_to_string(path).unwrap_or_else(|e| panic!("{}: {e}", path.display()))
}

/// The serial ground truth: the resilience experiment run in-process at
/// `--threads 1`, exactly as `experiments resilience --quick` would.
fn serial_resilience(out_dir: &Path) {
    let ctx = ExpCtx {
        jobs: JOBS,
        seed: 0,
        out_dir: out_dir.to_path_buf(),
        quick: true,
        fault_rate: 0.0,
        fault_seed: 0,
        threads: 1,
    };
    resilience::resilience(&ctx).unwrap();
}

fn resilience_sweep() -> SweepSpec {
    SweepSpec::Resilience { jobs: JOBS, seed: 0, quick: true, fault_seed: 0 }
}

fn base_opts(out_dir: &Path) -> DispatchOpts {
    DispatchOpts {
        workers: 3,
        out_dir: out_dir.to_path_buf(),
        worker_bin: Some(worker_bin()),
        fresh: true,
        ..Default::default()
    }
}

fn assert_same_artifacts(serial: &Path, fabric: &Path, name: &str) {
    for ext in ["json", "csv"] {
        let a = serial.join(format!("{name}.{ext}"));
        let b = fabric.join(format!("{name}.{ext}"));
        assert_eq!(read(&a), read(&b), "{name}.{ext} must be byte-identical to the serial run");
    }
}

#[test]
fn dispatch_matches_serial_and_resumes_from_a_truncated_journal() {
    let serial = tmp("serial");
    let fabric = tmp("fabric");
    serial_resilience(&serial);

    let sweep = resilience_sweep();
    let report = dispatch(&sweep, &base_opts(&fabric)).unwrap();
    assert_eq!((report.cells, report.resumed, report.executed), (CELLS, 0, CELLS));
    assert_same_artifacts(&serial, &fabric, "resilience");

    // interrupt: keep the header + the first 4 journaled cells, as if
    // the dispatch died mid-run, then resume without --fresh
    let journal = fabric.join("resilience.journal.jsonl");
    let kept: Vec<String> =
        read(&journal).lines().take(1 + 4).map(str::to_string).collect();
    assert_eq!(kept.len(), 1 + 4, "the first dispatch must have journaled every cell");
    std::fs::write(&journal, format!("{}\n", kept.join("\n"))).unwrap();

    let opts = DispatchOpts { fresh: false, ..base_opts(&fabric) };
    let report = dispatch(&sweep, &opts).unwrap();
    assert_eq!(
        (report.resumed, report.executed),
        (4, CELLS - 4),
        "resume must re-run exactly the un-journaled cells: {report:?}"
    );
    assert_same_artifacts(&serial, &fabric, "resilience");
}

#[test]
fn chaos_kills_and_stalls_change_nothing_but_the_clock() {
    let serial = tmp("chaos_serial");
    serial_resilience(&serial);
    let sweep = resilience_sweep();

    // every cell's first attempt kills its worker: all nine cells must
    // complete via crash detection + re-queue on respawned workers
    let fabric = tmp("chaos_kill");
    let opts = DispatchOpts {
        chaos: Some(ChaosConfig { kill_prob: 1.0, stall_prob: 0.0, ..Default::default() }),
        ..base_opts(&fabric)
    };
    let report: DispatchReport = dispatch(&sweep, &opts).unwrap();
    assert_eq!(report.chaos_kills, CELLS, "{report:?}");
    assert!(report.worker_deaths >= 2, "the run must survive multiple worker deaths: {report:?}");
    assert!(report.retries >= CELLS, "every killed cell must be re-queued: {report:?}");
    assert_eq!(report.executed, CELLS, "{report:?}");
    assert_same_artifacts(&serial, &fabric, "resilience");

    // every cell's first attempt stalls: completion may race a
    // straggler re-issue, and whoever wins must not change the bytes
    let fabric = tmp("chaos_stall");
    let opts = DispatchOpts {
        chaos: Some(ChaosConfig {
            kill_prob: 0.0,
            stall_prob: 1.0,
            stall_ms: 300,
            ..Default::default()
        }),
        ..base_opts(&fabric)
    };
    let report = dispatch(&sweep, &opts).unwrap();
    assert_eq!(report.chaos_stalls, CELLS, "{report:?}");
    assert_eq!(report.executed, CELLS, "{report:?}");
    assert_same_artifacts(&serial, &fabric, "resilience");
}

#[test]
fn generic_scenario_dispatch_matches_serial() {
    let sc = Scenario {
        name: "fabric_gen".into(),
        policies: vec!["SSGD".into(), "STAR-H".into()],
        archs: vec![Arch::Ps],
        ..Default::default()
    };
    let serial = tmp("gen_serial");
    scenario::run(
        &sc,
        &RunOpts { quick: true, jobs_override: Some(JOBS), threads: 1, out_dir: serial.clone() },
    )
    .unwrap();

    let fabric = tmp("gen_fabric");
    let sweep = SweepSpec::from_scenario(&sc, Some(JOBS), true).unwrap();
    let report = dispatch(&sweep, &base_opts(&fabric)).unwrap();
    assert_eq!(report.executed, 2, "{report:?}");
    assert_same_artifacts(&serial, &fabric, "scenario_fabric_gen");
}

/// Pin the artifact schema the fabric merge reproduces (DESIGN.md §10):
/// PR 6 intentionally dropped `threads` from the generic invocation
/// block (artifacts are run-invariant) and added `fault_rate` to every
/// resilience result row. Both were silent drifts at the time; this
/// test makes the next writer change loud instead.
#[test]
fn artifact_schema_pins_the_run_invariant_contract() {
    // resilience rows carry their grid coordinate as fault_rate
    let serial = tmp("schema_res");
    serial_resilience(&serial);
    let doc = Json::parse_file(&serial.join("resilience.json")).unwrap();
    let results = doc.get("results").unwrap().arr().unwrap();
    assert_eq!(results.len(), CELLS);
    for r in results {
        let rate = r.get("fault_rate").expect("every resilience row names its fault_rate");
        assert!(rate.num().unwrap() >= 0.0);
    }

    // generic invocation block: exactly {jobs, max_job_duration_s,
    // quick} — threads (and any fleet shape) deliberately absent, even
    // when the run was thread-parallel
    let sc = Scenario {
        name: "schema_gen".into(),
        policies: vec!["SSGD".into()],
        archs: vec![Arch::Ps],
        ..Default::default()
    };
    let out = tmp("schema_gen");
    scenario::run(
        &sc,
        &RunOpts { quick: true, jobs_override: Some(JOBS), threads: 2, out_dir: out.clone() },
    )
    .unwrap();
    let doc = Json::parse_file(&out.join("scenario_schema_gen.json")).unwrap();
    let inv = doc.get("invocation").unwrap().obj().unwrap();
    let keys: Vec<&str> = inv.keys().map(|k| k.as_str()).collect();
    assert_eq!(
        keys,
        ["jobs", "max_job_duration_s", "quick"],
        "the invocation block is run-invariant: threads must never be recorded"
    );
}

/// The tentpole's acceptance contract: a scenario-space search
/// dispatched over the fabric under full chaos produces byte-identical
/// sensitivity/regret artifacts to the serial in-process run.
#[test]
fn space_search_dispatch_under_chaos_matches_serial() {
    let space = find_space("mode_choice").unwrap();
    let (count, points) = (2, 2);

    let serial = tmp("space_serial");
    let opts = SearchOpts {
        count,
        points,
        quick: true,
        jobs_override: Some(JOBS),
        threads: 1,
        out_dir: serial.clone(),
    };
    search::run(&space, &opts).unwrap();

    let fabric = tmp("space_fabric");
    let sweep = SweepSpec::from_space(&space, count, points, Some(JOBS), true).unwrap();
    let cells = sweep.cell_labels().unwrap().len();
    let opts = DispatchOpts {
        chaos: Some(ChaosConfig { kill_prob: 1.0, stall_prob: 0.0, ..Default::default() }),
        ..base_opts(&fabric)
    };
    let report = dispatch(&sweep, &opts).unwrap();
    assert_eq!(report.executed, cells, "{report:?}");
    assert_eq!(report.chaos_kills, cells, "every first attempt dies: {report:?}");
    for name in
        ["search_mode_choice", "search_mode_choice_sensitivity", "search_mode_choice_regret"]
    {
        let ext = if name == "search_mode_choice" { vec!["json", "csv"] } else { vec!["csv"] };
        for e in ext {
            let a = serial.join(format!("{name}.{e}"));
            let b = fabric.join(format!("{name}.{e}"));
            assert_eq!(read(&a), read(&b), "{name}.{e} must survive chaos byte-identically");
        }
    }
}

#[test]
fn foreign_journal_is_refused_without_fresh() {
    let fabric = tmp("foreign");
    let path = fabric.join("resilience.journal.jsonl");
    // a journal from some other sweep (different fingerprint)
    drop(Journal::open(&path, "some-other-sweep", CELLS, false).unwrap());

    let opts = DispatchOpts { fresh: false, ..base_opts(&fabric) };
    let err = dispatch(&resilience_sweep(), &opts).unwrap_err();
    assert!(format!("{err:#}").contains("--fresh"), "{err:#}");
}

#[test]
fn broken_worker_binary_fails_instead_of_hanging() {
    let fabric = tmp("broken_bin");
    let opts = DispatchOpts {
        workers: 2,
        worker_bin: Some(PathBuf::from("/bin/false")),
        ..base_opts(&fabric)
    };
    let err = dispatch(&resilience_sweep(), &opts).unwrap_err();
    assert!(format!("{err:#}").contains("respawn budget"), "{err:#}");
}

#[test]
fn tcp_worker_serves_dispatches_and_survives_them() {
    // a 1-cell generic sweep keeps this smoke test fast
    let sc = Scenario {
        name: "fabric_tcp".into(),
        policies: vec!["SSGD".into()],
        archs: vec![Arch::Ps],
        ..Default::default()
    };
    let sweep = SweepSpec::from_scenario(&sc, Some(JOBS), true).unwrap();

    let mut worker = std::process::Command::new(worker_bin())
        .args(["worker", "--listen", "127.0.0.1:0"])
        .stdout(std::process::Stdio::piped())
        .spawn()
        .unwrap();
    let mut line = String::new();
    std::io::BufReader::new(worker.stdout.take().unwrap()).read_line(&mut line).unwrap();
    let addr = line
        .trim()
        .strip_prefix("star worker listening on ")
        .unwrap_or_else(|| panic!("unexpected announce line {line:?}"))
        .to_string();

    let run = |tag: &str| -> DispatchReport {
        let out = tmp(tag);
        let opts = DispatchOpts {
            connect: vec![addr.clone()],
            out_dir: out.clone(),
            fresh: true,
            ..Default::default()
        };
        let report = dispatch(&sweep, &opts).unwrap();
        assert!(out.join("scenario_fabric_tcp.json").is_file());
        report
    };
    // two dispatches against the same worker: it must outlive the first
    let r1 = run("tcp_a");
    let r2 = run("tcp_b");
    assert_eq!((r1.executed, r2.executed), (1, 1));

    let _ = worker.kill();
    let _ = worker.wait();
}

/// The pipelining + group-commit acceptance contract (DESIGN.md §14):
/// at `--window 4` the fleet needs fewer than half the protocol
/// round-trips of lock-step `--window 1`, and batched journal commits
/// fsync once per batch instead of once per cell — all without moving a
/// single artifact byte.
#[test]
fn pipelining_cuts_round_trips_and_group_commit_cuts_fsyncs() {
    let serial = tmp("pipe_serial");
    serial_resilience(&serial);
    let sweep = resilience_sweep();

    // lock-step + per-cell durability: every cell is a full round-trip
    // and every record its own fsync (straggler re-issue parked so the
    // round-trip count is exact)
    let a = tmp("pipe_lockstep");
    let opts = DispatchOpts {
        window: 1,
        commit_batch: 1,
        commit_interval_ms: 60_000,
        straggler_factor: 1e9,
        ..base_opts(&a)
    };
    let ra = dispatch(&sweep, &opts).unwrap();
    assert_eq!(ra.executed, CELLS, "{ra:?}");
    assert_eq!(ra.round_trips, CELLS, "window 1 pays one round-trip per cell: {ra:?}");
    assert_eq!(ra.journal_fsyncs, CELLS as u64, "batch 1 syncs every record: {ra:?}");
    assert_same_artifacts(&serial, &a, "resilience");

    // pipelined + group-committed: only the first issue per worker finds
    // it idle (3 workers ⇒ 3 round-trips); one batch commit plus the
    // final-tail flush cover all nine records
    let b = tmp("pipe_windowed");
    let opts = DispatchOpts {
        window: 4,
        commit_batch: 8,
        commit_interval_ms: 60_000,
        straggler_factor: 1e9,
        ..base_opts(&b)
    };
    let rb = dispatch(&sweep, &opts).unwrap();
    assert_eq!(rb.executed, CELLS, "{rb:?}");
    assert!(
        2 * rb.round_trips < ra.round_trips,
        "window 4 must need < half the round-trips of window 1: {} vs {}",
        rb.round_trips,
        ra.round_trips
    );
    assert_eq!(
        rb.journal_fsyncs, 2,
        "batch 8 over 9 cells is one batch commit + the final tail: {rb:?}"
    );
    assert_same_artifacts(&serial, &b, "resilience");
}

/// A heterogeneous fleet: one chaos-stalled slow worker among three
/// fast ones. The EWMA scheduler must route most cells to the fast
/// workers, the journal must hold each cell exactly once (straggler
/// duplicates race, but only one result lands), and the artifacts must
/// still match the serial run byte for byte.
#[test]
fn heterogeneous_fleet_balances_away_from_the_slow_worker() {
    let serial = tmp("hetero_serial");
    serial_resilience(&serial);
    let sweep = resilience_sweep();

    let fabric = tmp("hetero_fabric");
    let opts = DispatchOpts {
        workers: 4,
        window: 4,
        chaos: Some(ChaosConfig {
            kill_prob: 0.0,
            stall_prob: 0.0,
            slow_worker: Some(0),
            slow_ms: 1_500,
            ..Default::default()
        }),
        ..base_opts(&fabric)
    };
    let report = dispatch(&sweep, &opts).unwrap();
    assert_eq!(report.executed, CELLS, "{report:?}");
    assert_same_artifacts(&serial, &fabric, "resilience");

    let balance = &report.per_worker_cells;
    assert_eq!(balance.len(), 4, "{report:?}");
    assert_eq!(balance.iter().sum::<usize>(), CELLS, "every fresh result is credited");
    assert!(
        balance[1..].iter().sum::<usize>() > balance[0],
        "the fast workers must out-complete the stalled one: {balance:?}"
    );

    // the journal is the durability ledger: exactly one record per cell,
    // no matter how many duplicate attempts raced
    let journal = read(&fabric.join("resilience.journal.jsonl"));
    let mut indices: Vec<u64> = journal
        .lines()
        .skip(1) // header
        .map(|l| Json::parse(l).unwrap().get("index").unwrap().u64().unwrap())
        .collect();
    indices.sort_unstable();
    indices.dedup();
    assert_eq!(indices.len(), CELLS, "each cell must be journaled exactly once");
}

/// Group commit's crash contract: records buffered past the last fsync
/// are simply gone, and a resumed dispatch re-runs exactly those cells
/// — no more (the synced prefix is honored), no less (nothing
/// half-written sneaks in).
#[test]
fn group_commit_crash_reruns_exactly_the_unsynced_tail() {
    let serial = tmp("gc_crash_serial");
    serial_resilience(&serial);
    let sweep = resilience_sweep();

    // hand-build the pre-crash journal: 6 cells appended, only the
    // first 4 committed, then the process "dies" mid-batch
    let fabric = tmp("gc_crash_fabric");
    let path = fabric.join("resilience.journal.jsonl");
    let (mut j, _) = Journal::open(&path, &sweep.fingerprint(), CELLS, true).unwrap();
    for i in 0..6 {
        let rows = sweep.compute(i).unwrap();
        j.append(&CellDone { index: i, elapsed_s: 0.5, rows });
        if i == 3 {
            j.flush().unwrap();
        }
    }
    assert_eq!(j.pending(), 2, "cells 4 and 5 must still be buffered");
    j.abandon(); // the crash: the unsynced tail never hits the disk

    let opts = DispatchOpts { fresh: false, ..base_opts(&fabric) };
    let report = dispatch(&sweep, &opts).unwrap();
    assert_eq!(
        (report.resumed, report.executed),
        (4, CELLS - 4),
        "resume must re-run exactly the cells whose batch never synced: {report:?}"
    );
    assert_same_artifacts(&serial, &fabric, "resilience");
}

/// Satellite contract: a remote worker that is down when the dispatch
/// starts (killed, not yet restarted) is re-dialed on the backoff
/// schedule and rejoins mid-dispatch once `star worker --listen` comes
/// back on its address.
#[test]
fn tcp_dispatch_redials_until_a_restarted_worker_rejoins() {
    let sc = Scenario {
        name: "fabric_rejoin".into(),
        policies: vec!["SSGD".into()],
        archs: vec![Arch::Ps],
        ..Default::default()
    };
    let sweep = SweepSpec::from_scenario(&sc, Some(JOBS), true).unwrap();

    // reserve a port the OS considers free, then release it: the
    // dispatch dials an address nothing listens on (the "killed worker")
    let addr = {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        listener.local_addr().unwrap().to_string()
    };

    let out = tmp("rejoin_out");
    let opts = DispatchOpts {
        connect: vec![addr.clone()],
        out_dir: out.clone(),
        backoff_ms: 50,
        fresh: true,
        ..Default::default()
    };
    let dispatcher = std::thread::spawn(move || dispatch(&sweep, &opts));

    // let a few dials fail against the dead address, then "restart" the
    // worker on it mid-dispatch
    std::thread::sleep(std::time::Duration::from_millis(400));
    let mut worker = std::process::Command::new(worker_bin())
        .args(["worker", "--listen", &addr])
        .stdout(std::process::Stdio::piped())
        .spawn()
        .unwrap();
    let mut line = String::new();
    std::io::BufReader::new(worker.stdout.take().unwrap()).read_line(&mut line).unwrap();
    assert!(line.contains(&addr), "the restarted worker must bind the same address: {line:?}");

    let report = dispatcher.join().unwrap().unwrap();
    assert_eq!(report.executed, 1, "{report:?}");
    assert!(
        report.worker_reconnects >= 1,
        "the restarted worker must be counted as a re-join: {report:?}"
    );
    assert!(out.join("scenario_fabric_rejoin.json").is_file());

    let _ = worker.kill();
    let _ = worker.wait();
}
