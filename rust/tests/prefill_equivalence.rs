//! The prefill contract (DESIGN.md §13): parallel share-epoch prefill is
//! a pure performance optimization — replaying a full trace at
//! `prefill_threads: N` must be **bit-identical** to the serial lazy-fill
//! path at `prefill_threads: 1`, for every N. This holds because (a) an
//! epoch fill is a pure function of (server state, res, t) drawing only
//! from per-server deterministic streams, never from the driver RNG,
//! (b) the driver prefills exactly the epochs the imminent round will
//! query, after `decide` has applied its cap churn, and (c) distinct
//! (server, res) epochs touch disjoint mutable state, so scoped-thread
//! fills cannot race. Faults are on: recovery restarts, pauses, and
//! membership churn are where a stale or early fill would first diverge.

use star::baselines::make_policy;
use star::driver::{Driver, DriverConfig, JobStats, RunMetrics, ServerRecord};
use star::faults::span_for;
use star::scenario::FaultRegime;
use star::trace::{generate, Arch, TraceConfig};

fn run(
    arch: Arch,
    system: &str,
    prefill_threads: usize,
) -> (Vec<JobStats>, Vec<ServerRecord>, RunMetrics) {
    let trace = generate(&TraceConfig { jobs: 8, span_s: 2000.0, ..Default::default() });
    let mut cfg = DriverConfig {
        arch,
        record_series: true,
        server_sample_period_s: 200.0,
        prefill_threads,
        ..Default::default()
    };
    // fault-heavy: rate 2 triggers kills, pauses, and FirstK membership
    // churn — the paths where prefill eligibility must mirror
    // start_iteration exactly
    cfg.faults = FaultRegime::Rate { rate: 2.0, seed: 7 }.plan(
        &trace,
        span_for(&trace, cfg.max_job_duration_s),
        cfg.cluster.total_servers(),
    );
    let name = system.to_string();
    let driver =
        Driver::new(cfg, trace, Box::new(move |_| make_policy(&name).expect("known system")));
    driver.run_instrumented()
}

/// Every field compared with exact equality — "close" is not good enough:
/// prefill must not perturb a single RNG draw or float operation.
fn assert_bit_identical(a: &[JobStats], b: &[JobStats]) {
    assert_eq!(a.len(), b.len(), "job count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.job, y.job);
        assert_eq!(x.system, y.system);
        assert_eq!(x.start_s, y.start_s, "job {}", x.job);
        assert_eq!(x.end_s, y.end_s, "job {}", x.job);
        assert_eq!(x.tta_s, y.tta_s, "job {} TTA", x.job);
        assert_eq!(x.jct_s, y.jct_s, "job {} JCT", x.job);
        assert_eq!(x.converged_value, y.converged_value, "job {}", x.job);
        assert_eq!(x.updates, y.updates, "job {}", x.job);
        assert_eq!(x.iters_total, y.iters_total, "job {}", x.job);
        assert_eq!(x.straggler_iters, y.straggler_iters, "job {}", x.job);
        assert_eq!(x.straggler_episodes, y.straggler_episodes, "job {}", x.job);
        assert_eq!(x.mode_switches, y.mode_switches, "job {}", x.job);
        assert_eq!(x.decision_count, y.decision_count, "job {}", x.job);
        assert_eq!(x.prediction.tp, y.prediction.tp, "job {}", x.job);
        assert_eq!(x.prediction.fp, y.prediction.fp, "job {}", x.job);
        assert_eq!(x.prediction.tn, y.prediction.tn, "job {}", x.job);
        assert_eq!(x.prediction.fn_, y.prediction.fn_, "job {}", x.job);
        assert_eq!(x.decision_pause_total_s, y.decision_pause_total_s, "job {}", x.job);
        assert_eq!(x.value_series, y.value_series, "job {}", x.job);
        // per-iteration breakdowns: the rawest observable of the share path
        assert_eq!(x.series.len(), y.series.len());
        for (sw, dw) in x.series.iter().zip(&y.series) {
            assert_eq!(sw.len(), dw.len(), "job {} series length", x.job);
            for (si, di) in sw.iter().zip(dw) {
                assert_eq!(si.pre_s, di.pre_s, "job {}", x.job);
                assert_eq!(si.gpu_s, di.gpu_s, "job {}", x.job);
                assert_eq!(si.comm_s, di.comm_s, "job {}", x.job);
                assert_eq!(si.total_s, di.total_s, "job {}", x.job);
                assert_eq!(si.cpu_share, di.cpu_share, "job {}", x.job);
                assert_eq!(si.bw_share, di.bw_share, "job {}", x.job);
            }
        }
    }
}

fn assert_records_identical(a: &[ServerRecord], b: &[ServerRecord]) {
    assert_eq!(a.len(), b.len(), "record count");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.time, y.time);
        assert_eq!(x.server, y.server);
        assert_eq!(x.ps_hosted, y.ps_hosted);
        assert_eq!(x.cpu_util, y.cpu_util, "server {} t {}", x.server, x.time);
        assert_eq!(x.bw_util, y.bw_util, "server {} t {}", x.server, x.time);
    }
}

/// The counters must match too: prefill may not add or skip a fill
/// relative to the lazy path (eligibility mirrors start_iteration), and
/// the event stream must be untouched.
fn assert_metrics_identical(a: &RunMetrics, b: &RunMetrics) {
    assert_eq!(a.events, b.events, "event count");
    assert_eq!(a.epoch_fills, b.epoch_fills, "fill count");
    assert_eq!(a.peak_queue_depth, b.peak_queue_depth, "queue depth");
    assert_eq!(a.jobs_finished, b.jobs_finished, "jobs finished");
}

#[test]
fn prefill_replay_is_bit_identical_ps() {
    let (serial, serial_recs, serial_m) = run(Arch::Ps, "STAR-H", 1);
    let (par, par_recs, par_m) = run(Arch::Ps, "STAR-H", 4);
    assert_bit_identical(&serial, &par);
    assert_records_identical(&serial_recs, &par_recs);
    assert_metrics_identical(&serial_m, &par_m);
}

#[test]
fn prefill_replay_is_bit_identical_ar() {
    let (serial, serial_recs, serial_m) = run(Arch::AllReduce, "STAR-H", 1);
    let (par, par_recs, par_m) = run(Arch::AllReduce, "STAR-H", 4);
    assert_bit_identical(&serial, &par);
    assert_records_identical(&serial_recs, &par_recs);
    assert_metrics_identical(&serial_m, &par_m);
}

#[test]
fn prefill_replay_is_bit_identical_for_round_burst_baseline() {
    // SSGD starts whole groups at one instant — the widest prefill batch
    // per round and the cache's sweet spot — without STAR's cap churn
    let (serial, _, serial_m) = run(Arch::Ps, "SSGD", 1);
    let (par, _, par_m) = run(Arch::Ps, "SSGD", 4);
    assert_bit_identical(&serial, &par);
    assert_metrics_identical(&serial_m, &par_m);
}
