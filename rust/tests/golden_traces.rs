//! Golden-trace regression suite: a small seeded PS and AR replay —
//! *including* a seeded fault plan, so the resilience machinery is under
//! regression too — runs through every policy (STAR-H/ML/Early + the six
//! §V baselines); the resulting `Summary` metrics are snapshotted to
//! `tests/golden/{ps,ar}.json` and compared within 1e-9.
//!
//! Workflow (DESIGN.md §7.3):
//! * normal runs compare against the committed snapshots and fail on any
//!   drift — an unintended semantic change in the simulator, a policy,
//!   or the fault engine shows up as a diff here;
//! * `GOLDEN_UPDATE=1 cargo test --test golden_traces` regenerates the
//!   snapshots after an *intended* change (commit the diff);
//! * a missing snapshot file is bootstrapped on first run (and the run
//!   passes), so a fresh checkout without goldens self-heals — commit
//!   the generated files to arm the regression.

use std::collections::BTreeMap;
use std::path::PathBuf;

use star::baselines::make_policy;
use star::driver::{Driver, DriverConfig, JobStats};
use star::exp::summarize;
use star::faults::{generate_plan, FaultConfig};
use star::jsonio::{self, Json};
use star::trace::{generate, Arch, TraceConfig};

/// Every policy of the §V evaluation: STAR-H / STAR-ML / STAR- (early)
/// plus the six comparison systems.
const POLICIES: [&str; 9] = [
    "SSGD",
    "ASGD",
    "Sync-Switch",
    "LB-BSP",
    "LGC",
    "Zeno++",
    "STAR-H",
    "STAR-ML",
    "STAR-",
];

const TRACE_SEED: u64 = 42;
const FAULT_SEED: u64 = 9;

fn build_driver(arch: Arch, system: &str) -> Driver {
    let trace =
        generate(&TraceConfig { jobs: 3, span_s: 300.0, seed: TRACE_SEED, ..Default::default() });
    let faults = generate_plan(
        &FaultConfig { seed: FAULT_SEED, ..Default::default() }.with_rate(3.0),
        &trace,
        6000.0,
        8,
    );
    let cfg = DriverConfig {
        arch,
        seed: TRACE_SEED,
        record_series: false,
        max_updates_per_job: 2500,
        max_iters_per_job: 5000,
        max_job_duration_s: 5000.0,
        faults,
        ..Default::default()
    };
    let name = system.to_string();
    Driver::new(cfg, trace, Box::new(move |_| make_policy(&name).expect("known system")))
}

fn replay(arch: Arch, system: &str) -> Vec<JobStats> {
    build_driver(arch, system).run().0
}

/// Summary metrics of one policy's replay as a JSON object.
fn snapshot(stats: &[JobStats]) -> Json {
    let s = summarize(stats);
    let updates: u64 = stats.iter().map(|x| x.updates).sum();
    let iters: u64 = stats.iter().map(|x| x.iters_total).sum();
    jsonio::obj(vec![
        ("tta", jsonio::nums(&s.tta)),
        ("jct", jsonio::nums(&s.jct)),
        ("acc", jsonio::nums(&s.acc)),
        ("ppl", jsonio::nums(&s.ppl)),
        ("stragglers", jsonio::nums(&s.stragglers)),
        ("downtime", jsonio::nums(&s.downtime)),
        ("rollbacks", jsonio::nums(&s.rollbacks)),
        ("tta_reached", jsonio::num(s.tta_reached as f64)),
        ("jobs", jsonio::num(s.jobs as f64)),
        ("updates", jsonio::num(updates as f64)),
        ("iters", jsonio::num(iters as f64)),
    ])
}

fn golden_path(file: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden").join(file)
}

fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0)
}

/// Structural diff with 1e-9 numeric tolerance; appends one line per
/// mismatch so a drift report names every affected metric.
fn diff(path: &str, want: &Json, got: &Json, errs: &mut Vec<String>) {
    match (want, got) {
        (Json::Num(a), Json::Num(b)) => {
            if !close(*a, *b) {
                errs.push(format!("{path}: {a} != {b}"));
            }
        }
        (Json::Arr(a), Json::Arr(b)) => {
            if a.len() != b.len() {
                errs.push(format!("{path}: length {} != {}", a.len(), b.len()));
                return;
            }
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                diff(&format!("{path}[{i}]"), x, y, errs);
            }
        }
        (Json::Obj(a), Json::Obj(b)) => {
            for key in a.keys().chain(b.keys().filter(|k| !a.contains_key(*k))) {
                match (a.get(key), b.get(key)) {
                    (Some(x), Some(y)) => diff(&format!("{path}/{key}"), x, y, errs),
                    (Some(_), None) => errs.push(format!("{path}/{key}: missing in new run")),
                    (None, Some(_)) => errs.push(format!("{path}/{key}: not in golden file")),
                    (None, None) => unreachable!(),
                }
            }
        }
        (a, b) => {
            if a != b {
                errs.push(format!("{path}: {a:?} != {b:?}"));
            }
        }
    }
}

fn run_golden(arch: Arch, file: &str) {
    let mut doc: BTreeMap<String, Json> = BTreeMap::new();
    for sys in POLICIES {
        doc.insert(sys.to_string(), snapshot(&replay(arch, sys)));
    }
    let got = Json::Obj(doc);

    let path = golden_path(file);
    let update = std::env::var("GOLDEN_UPDATE").map(|v| v == "1").unwrap_or(false);
    if update || !path.exists() {
        // GOLDEN_REQUIRE=1 (for CI once snapshots are committed) turns a
        // missing snapshot into a failure instead of a silent bootstrap —
        // bootstrap-against-self can never detect cross-commit drift
        let require = std::env::var("GOLDEN_REQUIRE").map(|v| v == "1").unwrap_or(false);
        assert!(
            update || !require,
            "golden snapshot {} is missing and GOLDEN_REQUIRE=1",
            path.display()
        );
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, got.to_string_pretty()).unwrap();
        eprintln!(
            "golden: {} {}",
            if update { "regenerated" } else { "bootstrapped (commit it to arm the regression)" },
            path.display()
        );
        return;
    }

    let want = Json::parse_file(&path).unwrap();
    let mut errs = Vec::new();
    diff("", &want, &got, &mut errs);
    assert!(
        errs.is_empty(),
        "golden drift vs {} ({} metric(s)):\n  {}\n\
         If this change is intended, regenerate with:\n  \
         GOLDEN_UPDATE=1 cargo test --test golden_traces",
        path.display(),
        errs.len(),
        errs.join("\n  ")
    );
}

#[test]
fn golden_ps_replay_all_policies() {
    run_golden(Arch::Ps, "ps.json");
}

#[test]
fn golden_ar_replay_all_policies() {
    run_golden(Arch::AllReduce, "ar.json");
}

// ---------------------------------------------------------------------------
// Determinism: the same trace + fault plan must replay bit-identically
// ---------------------------------------------------------------------------

fn assert_bit_identical(a: &[JobStats], b: &[JobStats]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.job, y.job);
        assert_eq!(x.start_s, y.start_s, "job {}", x.job);
        assert_eq!(x.end_s, y.end_s, "job {}", x.job);
        assert_eq!(x.tta_s, y.tta_s, "job {}", x.job);
        assert_eq!(x.jct_s, y.jct_s, "job {}", x.job);
        assert_eq!(x.converged_value, y.converged_value, "job {}", x.job);
        assert_eq!(x.updates, y.updates, "job {}", x.job);
        assert_eq!(x.iters_total, y.iters_total, "job {}", x.job);
        assert_eq!(x.straggler_iters, y.straggler_iters, "job {}", x.job);
        assert_eq!(x.straggler_episodes, y.straggler_episodes, "job {}", x.job);
        assert_eq!(x.mode_switches, y.mode_switches, "job {}", x.job);
        assert_eq!(x.downtime_s, y.downtime_s, "job {}", x.job);
        assert_eq!(x.rollbacks, y.rollbacks, "job {}", x.job);
        assert_eq!(x.decision_count, y.decision_count, "job {}", x.job);
        assert_eq!(x.value_series, y.value_series, "job {}", x.job);
    }
}

#[test]
fn faulted_replay_is_bit_identical_including_event_counts() {
    // pins the Engine's FIFO tie-break and the per-stream `simrng`
    // discipline: identical inputs must produce identical event machines,
    // down to the number of processed events
    for arch in [Arch::Ps, Arch::AllReduce] {
        for sys in ["SSGD", "STAR-H"] {
            let (a, _, ea) = build_driver(arch, sys).run_counted();
            let (b, _, eb) = build_driver(arch, sys).run_counted();
            assert_eq!(ea, eb, "{sys} {arch:?}: event counts diverged");
            assert_bit_identical(&a, &b);
        }
    }
}

#[test]
fn fault_plan_actually_bites_in_golden_runs() {
    // the goldens must exercise the fault machinery, not just tolerate it
    let stats = replay(Arch::Ps, "SSGD");
    let downtime: f64 = stats.iter().map(|s| s.downtime_s).sum();
    let rollbacks: u64 = stats.iter().map(|s| s.rollbacks).sum();
    assert!(
        downtime > 0.0 || rollbacks > 0,
        "golden fault plan produced no observable failures"
    );
}
