//! PJRT runtime benchmarks: the real compute hot path — per-worker train
//! step, fused grad-acc/apply kernels, full x-order round — on the tiny
//! and base configs. Skips cleanly when artifacts are absent.

use star::benchkit::Bencher;
use star::runtime::{synth_corpus_batch, Manifest, Runtime, TrainSession};
use star::simrng::Rng;

fn main() {
    let man = match Manifest::discover() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping runtime bench: {e}");
            return;
        }
    };
    // skips cleanly when built without the `xla` feature, too
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping runtime bench: {e}");
            return;
        }
    };
    let mut b = Bencher::quick();
    let mut rng = Rng::seeded(2);

    for config in ["tiny", "base"] {
        if !man.config_names().iter().any(|n| n == config) {
            continue;
        }
        let mut s = TrainSession::new(&rt, &man, config).expect("session");
        s.init_params(0).expect("init");
        let info = s.info.clone();
        let toks = synth_corpus_batch(&info, &mut rng);
        let tokens_per_step = (info.batch * info.seq_len) as f64;

        b.bench(&format!("train_step [{config}] ({} params)", info.param_count), || {
            s.train_step(&toks).expect("step")
        });
        b.throughput("tokens", tokens_per_step);

        let (_, g) = s.train_step(&toks).expect("step");
        let acc = vec![0.0f32; info.padded_param_count];
        b.bench(&format!("grad_acc kernel [{config}]"), || {
            s.grad_acc(&acc, &g, 1.0).expect("acc")
        });
        b.throughput("params", info.padded_param_count as f64);

        b.bench(&format!("apply_update kernel [{config}]"), || {
            s.apply_update(&g, 0.0).expect("apply") // scale 0: params unchanged
        });
        b.throughput("params", info.padded_param_count as f64);

        let grads: Vec<Vec<f32>> = (0..4).map(|_| g.clone()).collect();
        b.bench(&format!("xorder_update x=4 [{config}]"), || {
            s.apply_update(&g, 0.0).expect("warm");
            s.xorder_update(&grads, 0.0).expect("xorder")
        });
    }

    // predictor artifact
    if let Ok(p) = star::runtime::LstmPredictor::new(&rt, &man) {
        let rows: Vec<[f32; 2]> = (0..32).map(|i| [0.5 + 0.01 * i as f32, 0.4]).collect();
        b.bench("LSTM predictor artifact", || p.predict_rows(&rows).expect("lstm"));
    }

    b.write_json_env("BENCH_runtime.json");
}
