//! Coordinator hot-loop benchmarks: round planning per mode, gradient
//! aggregation (pure-Rust fallback vs naive), comm-tree construction,
//! prediction pipeline, resource shares (the per-iteration inner loop) —
//! both the epoch-fill path and the cached-lookup path.

use star::agg;
use star::benchkit::Bencher;
use star::cluster::{Cluster, ClusterConfig, Res, Role, Task};
use star::predict::{ArPredictor, History, IterTimeModel, ResourcePredictor};
use star::prevent::CommTree;
use star::simrng::Rng;
use star::sync::{plan_round, SyncMode};

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::seeded(5);

    let times: Vec<f64> = (0..12).map(|_| rng.range(0.2, 2.0)).collect();
    for mode in [
        SyncMode::Ssgd,
        SyncMode::Asgd,
        SyncMode::StaticX(4),
        SyncMode::DynamicX,
        SyncMode::ArRing { removed: 2, tw_ms: 90.0 },
    ] {
        b.bench(&format!("plan_round {} (N=12)", mode.name()), || {
            plan_round(&mode, &times, &times)
        });
    }

    // gradient aggregation (1M params, 4 reports)
    let p = 1_000_000;
    let grads: Vec<Vec<f32>> = (0..4).map(|k| vec![0.1 * k as f32; p]).collect();
    let grefs: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    let mut params = vec![0.5f32; p];
    let mut scratch = vec![0.0f32; p];
    b.bench("xorder_update fused (1M params, x=4)", || {
        agg::xorder_update(&mut params, &grefs, 0.01, &mut scratch);
    });
    b.throughput("param", 4.0 * p as f64);
    let mut out = vec![0.0f32; p];
    b.bench("mean_naive (1M params, x=4)", || {
        agg::mean_naive(&grefs, &mut out);
    });

    // comm tree construction
    let bw: Vec<f64> = (0..12).map(|_| rng.range(0.5, 8.0)).collect();
    b.bench("CommTree::build (N=12, b=3)", || CommTree::build(&bw, 3));

    // prediction pipeline: history push + AR predict + regressor
    let mut h = History::new();
    for _ in 0..32 {
        h.push(rng.range(0.2, 1.0), rng.range(0.2, 1.0), 0.4);
    }
    let mut model = IterTimeModel::new();
    for _ in 0..64 {
        let x = IterTimeModel::features(250.0, 60.0, 30.0, rng.range(1.0, 4.0), rng.range(1.0, 6.0));
        model.observe(&x, rng.range(0.2, 1.5));
    }
    b.bench("predict pipeline (AR + ridge)", || {
        let (c, bw_) = ArPredictor.predict(&h);
        let x = IterTimeModel::features(250.0, 60.0, 30.0, c * 3.0, bw_ * 6.0);
        model.predict(&x)
    });

    // cluster shares: the per-iteration inner loop at realistic occupancy
    let mut c = Cluster::new(ClusterConfig::default());
    for j in 0..20 {
        c.add_task(Task {
            job: j,
            role: Role::Ps { idx: 0 },
            server: 0,
            cpu_demand: rng.range(1.0, 6.0),
            bw_demand: rng.range(0.3, 3.0),
            cpu_cap: 1.0,
            bw_cap: 1.0,
            cpu_throttle: 1.0,
            bw_throttle: 1.0,
            active: true,
        });
    }
    // epoch fill: every call advances time, so every call recomputes
    let mut t = 0.0;
    b.bench("cluster shares epoch fill (20 tasks)", || {
        t += 0.37;
        c.shares(0, Res::Cpu, t)
    });
    b.throughput("share-queries", 1.0);

    // cached lookups: the whole server queried per task at one instant —
    // one water-fill, 20 O(k) lookups (the SSGD round-start pattern).
    // Continues from the previous bench's clock: cluster query times must
    // be non-decreasing (spike pruning relies on it).
    let mut tc = t;
    b.bench("cluster share_of x20 cached (one epoch)", || {
        tc += 0.37;
        let mut sum = 0.0;
        for id in 0..20 {
            sum += c.share_of(id, Res::Cpu, tc);
        }
        sum
    });
    b.throughput("share-queries", 20.0);

    // allocation-free epoch fill: same water-fill, reused output buffer.
    // (The driver's own hot path batches through worker_shares/
    // bw_share_sum; shares_into/shares_view are the slice-returning
    // forms for whole-server consumers — tests, benches, tooling.)
    let mut tv = tc;
    let mut buf: Vec<(usize, f64)> = Vec::new();
    b.bench("cluster shares_into epoch fill (20 tasks)", || {
        tv += 0.37;
        c.shares_into(0, Res::Cpu, tv, &mut buf);
        buf.len()
    });
    b.throughput("share-queries", 1.0);

    b.write_json_env("BENCH_coordinator.json");
}
