//! End-to-end simulation benchmarks — one per §V table family: the full
//! trace replay that regenerates Figs 18–22 (per system), plus the raw
//! event-engine throughput. Writes `BENCH_sim.json` (schema
//! `star-bench-v1`) so CI can track trace-replay throughput across PRs.

use star::baselines::make_policy;
use star::benchkit::Bencher;
use star::cluster::{water_fill_into, water_fill_sorted};
use star::driver::{Driver, DriverConfig};
use star::sim::Engine;
use star::simrng::Rng;
use star::trace::{generate, Arch, TraceConfig};

fn main() {
    let mut b = Bencher::quick();

    // water-fill: full-sort (the pre-§13 every-fill path) vs sorted-reuse
    // (the generation-keyed cached-permutation path). Same demand vector,
    // over-capacity so both run the allocation pass; the delta is the
    // gather + stable sort the cache elides on epoch refills.
    for n in [10usize, 100, 1000] {
        let mut rng = Rng::seeded(42 ^ n as u64);
        let demands: Vec<f64> = (0..n).map(|_| rng.range(0.1, 4.0)).collect();
        let capacity = demands.iter().sum::<f64>() * 0.5;
        let d2 = demands.clone();
        b.bench(&format!("water_fill full-sort n={n}"), move || {
            let mut order = Vec::new();
            let mut alloc = Vec::new();
            let mut acc = 0.0f64;
            for _ in 0..100 {
                water_fill_into(&d2, capacity, &mut order, &mut alloc);
                acc += alloc[0];
            }
            acc
        });
        let d3 = demands.clone();
        b.bench(&format!("water_fill sorted-reuse n={n}"), move || {
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| d3[a].partial_cmp(&d3[b]).unwrap());
            let mut alloc = Vec::new();
            let mut acc = 0.0f64;
            for _ in 0..100 {
                water_fill_sorted(&d3, capacity, &order, &mut alloc);
                acc += alloc[0];
            }
            acc
        });
    }

    // raw event-engine throughput
    b.bench("sim::Engine 100k events", || {
        let mut e = Engine::new();
        let mut rng = Rng::seeded(1);
        for i in 0..100_000u32 {
            e.schedule_at(rng.range(0.0, 1e6), i);
        }
        let mut n = 0u32;
        while e.next().is_some() {
            n += 1;
        }
        n
    });
    b.throughput("events", 200_000.0);

    // per-system end-to-end trace runs (the Fig 18 row generators)
    for sys in ["SSGD", "ASGD", "LGC", "STAR-H", "STAR-ML"] {
        let name = sys.to_string();
        b.bench(&format!("trace replay 8 jobs [{sys}] (PS)"), move || {
            let trace =
                generate(&TraceConfig { jobs: 8, span_s: 2000.0, ..Default::default() });
            let cfg = DriverConfig { record_series: false, ..Default::default() };
            let n2 = name.clone();
            let (stats, _) =
                Driver::new(cfg, trace, Box::new(move |_| make_policy(&n2).expect("known system"))).run();
            stats.len()
        });
    }

    let name = "STAR-H".to_string();
    b.bench("trace replay 8 jobs [STAR-H] (AR)", move || {
        let trace = generate(&TraceConfig { jobs: 8, span_s: 2000.0, ..Default::default() });
        let cfg = DriverConfig {
            arch: Arch::AllReduce,
            record_series: false,
            ..Default::default()
        };
        let n2 = name.clone();
        let (stats, _) = Driver::new(cfg, trace, Box::new(move |_| make_policy(&n2).expect("known system"))).run();
        stats.len()
    });

    b.write_json_env("BENCH_sim.json");
}
