//! Decision-path benchmarks (Fig 28's hot path): STAR-H heuristic
//! enumeration, STAR-ML features+inference, dynamic clustering, LR scaling.
//! The paper's python heuristic costs ~970 ms per decision; these measure
//! the rust reimplementation (µs scale — see EXPERIMENTS.md §Perf).

use star::benchkit::Bencher;
use star::decide::{choose_ar_heuristic, choose_ps_heuristic, MlDecider};
use star::models::ZOO;
use star::simrng::Rng;
use star::sync::{candidate_modes_ps, cluster_times};

fn main() {
    let mut b = Bencher::new();
    let spec = &ZOO[4];
    let mut rng = Rng::seeded(3);

    for n in [4usize, 8, 12] {
        let pred: Vec<f64> = (0..n).map(|_| rng.range(0.2, 2.5)).collect();
        b.bench(&format!("STAR-H choose_ps (N={n})"), || {
            choose_ps_heuristic(spec, 150.0, n, &pred)
        });
    }

    let pred8: Vec<f64> = (0..8).map(|_| rng.range(0.2, 2.5)).collect();
    b.bench("STAR-H choose_ar (N=8, 7 t_w grid)", || {
        choose_ar_heuristic(spec, 150.0, 8, 3, &star::star::TW_GRID_MS, &pred8)
    });

    // trained ML decider
    let mut ml = MlDecider::new();
    for _ in 0..300 {
        let p: Vec<f64> = (0..8).map(|_| rng.range(0.2, 2.5)).collect();
        for m in candidate_modes_ps(8) {
            let est = star::decide::time_to_progress_ps(spec, 100.0, 8, &m, &p);
            ml.observe(&MlDecider::features(spec, 100.0, 8, &p, &m), est);
        }
    }
    b.bench("STAR-ML choose (N=8, trained)", || {
        ml.choose(spec, 150.0, 8, &pred8, candidate_modes_ps(8))
    });

    b.bench("dynamic clustering (N=12)", || {
        let p: Vec<f64> = (0..12).map(|_| rng.range(0.2, 2.5)).collect();
        cluster_times(&p, 0.15, 0.02)
    });

    b.bench("ridge online observe+fit (D=10)", || {
        let p: Vec<f64> = (0..8).map(|_| rng.range(0.2, 2.5)).collect();
        let x = MlDecider::features(spec, 100.0, 8, &p, &star::sync::SyncMode::Ssgd);
        ml.observe(&x, 1.0);
        ml.ridge.fit();
    });

    b.write_json_env("BENCH_decision.json");
}
