//! Offline stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io access, so this vendored shim
//! implements exactly the subset the workspace uses: [`Error`] (a
//! context-chain error), [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`]
//! macros, and the [`Context`] extension trait for `Result` and `Option`.
//! Formatting mirrors anyhow: `{}` prints the outermost message, `{:#}`
//! prints the whole chain separated by `": "`, `{:?}` prints the chain as
//! a `Caused by:` list.

use std::fmt;

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error carrying a chain of context messages (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message (what anyhow's `Context` does).
    pub fn context<C: fmt::Display>(mut self, c: C) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

// The same blanket conversion real anyhow relies on; coherent because
// `Error` itself does not implement `std::error::Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(::std::concat!(
                "condition failed: `",
                ::std::stringify!($cond),
                "`"
            ))
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        let e = std::fs::read_to_string("/definitely/not/a/file");
        e.context("reading config")?;
        Ok(())
    }

    #[test]
    fn context_chains_and_formats() {
        let err = fails_io().unwrap_err();
        assert_eq!(format!("{err}"), "reading config");
        let alt = format!("{err:#}");
        assert!(alt.starts_with("reading config: "), "{alt}");
        let dbg = format!("{err:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn macros_work() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                bail!("zero not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(0).unwrap_err()), "zero not allowed");
        assert_eq!(format!("{}", f(-2).unwrap_err()), "negative: -2");
        let e: Error = anyhow!("code {}", 7);
        assert_eq!(format!("{e}"), "code 7");
    }

    #[test]
    fn option_context() {
        let none: Option<u8> = None;
        let err = none.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{err}"), "missing key");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<f64> {
            Ok(s.parse::<f64>()?)
        }
        assert!(parse("1.5").is_ok());
        assert!(parse("nope").is_err());
    }

    #[test]
    fn anyhow_results_take_more_context() {
        fn inner() -> Result<()> {
            bail!("inner failure")
        }
        let err = inner().context("outer step").unwrap_err();
        assert_eq!(format!("{err:#}"), "outer step: inner failure");
    }
}
