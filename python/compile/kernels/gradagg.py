"""L1 Pallas kernels for STAR's x-order gradient aggregation + SGD apply.

The paper's synchronization modes (§IV-B) update parameters from the
gradients of x <= N workers. The aggregation/apply path is bandwidth-bound
(one pass over every parameter byte), so we fuse:

  * ``accumulate``:  acc' = acc + w * g      (one HBM pass per report)
  * ``sgd_apply``:   p'   = p - lr * (acc / count)   (fused scale + apply)

instead of the naive read-grads / read-params / write-params sequence —
one HBM round-trip per tensor per step rather than x + 2. Both kernels
operate on the *flattened* parameter vector (the runtime keeps params as a
single f32[P] buffer), tiled by BlockSpec over 1-D blocks: the TPU-side
analogue of a grid-stride elementwise CUDA kernel.

interpret=True: see matmul.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 64 Ki f32 per block = 256 KiB per operand tile; 3 operands resident
# -> 768 KiB VMEM, far under budget, and few grid steps even at P ~ 10^8.
DEFAULT_BLOCK_1D = 65536


def _pick_block(n: int, want: int) -> int:
    b = min(want, n)
    while n % b != 0:
        b -= 1
    return max(b, 1)


def _accum_kernel(acc_ref, g_ref, w_ref, o_ref):
    o_ref[...] = acc_ref[...] + w_ref[0] * g_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def accumulate(acc: jax.Array, g: jax.Array, w: jax.Array, block: int = DEFAULT_BLOCK_1D) -> jax.Array:
    """acc + w*g over flat f32[P]; w is f32[1] (gradient report weight)."""
    (p,) = acc.shape
    blk = _pick_block(p, block)
    return pl.pallas_call(
        _accum_kernel,
        grid=(p // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), jnp.float32),
        interpret=True,
    )(acc, g, w)


def _sgd_kernel(p_ref, acc_ref, scale_ref, o_ref):
    # scale = lr / count, folded on the host side into one scalar so the
    # kernel is a single fused multiply-subtract per element.
    o_ref[...] = p_ref[...] - scale_ref[0] * acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def sgd_apply(params: jax.Array, acc: jax.Array, scale: jax.Array, block: int = DEFAULT_BLOCK_1D) -> jax.Array:
    """p - scale*acc over flat f32[P]; scale is f32[1] = lr/num_reports."""
    (p,) = params.shape
    blk = _pick_block(p, block)
    return pl.pallas_call(
        _sgd_kernel,
        grid=(p // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), jnp.float32),
        interpret=True,
    )(params, acc, scale)


def hbm_traffic_bytes_fused(p: int, x_reports: int) -> int:
    """Bytes moved by the fused path for one x-order update."""
    # x accumulate passes (read acc+g, write acc) + 1 apply (read p+acc, write p)
    return x_reports * 3 * 4 * p + 3 * 4 * p


def hbm_traffic_bytes_naive(p: int, x_reports: int) -> int:
    """Naive: materialize mean grad, then separate axpy into params, with
    an extra full read/write for the division by count."""
    return (x_reports * 3 + 3 + 3) * 4 * p
