"""L1 Pallas kernel: tiled matmul for the transformer hot spot.

Hardware adaptation (paper targets A100/CUDA, we target the TPU model —
see DESIGN.md §Hardware-Adaptation): the CUDA idiom of threadblock tiling
with a shared-memory accumulator becomes a Pallas grid over (M/bm, N/bn,
K/bk) output-revisiting tiles. The K axis is the innermost grid dimension,
so each (i, j) output tile stays resident in VMEM while the kernel walks
the K strip — the same HBM↔VMEM schedule the paper's per-worker GEMMs get
from CUTLASS-style threadblock tiling. Block shapes default to 128×128,
the MXU systolic-array native tile.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same artifact runs
on the rust CPU client. Real-TPU perf is estimated analytically in
DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile edge. VMEM budget check (see DESIGN.md §Perf):
# bm*bk + bk*bn + bm*bn floats = 3*128*128*4 B = 192 KiB << 16 MiB VMEM.
DEFAULT_BLOCK = 128


def _pick_block(dim: int, want: int) -> int:
    """Largest divisor of `dim` that is <= want (prefers powers of two)."""
    b = min(want, dim)
    while dim % b != 0:
        b -= 1
    return max(b, 1)


def _matmul_kernel(x_ref, y_ref, o_ref, *, nk: int):
    """Output-revisiting accumulation: o[i,j] += x[i,k] @ y[k,j]."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # jnp.dot on f32 blocks maps onto the MXU (bf16 inputs would use the
    # native systolic datapath; we keep f32 for CPU-exact numerics).
    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_raw(
    x: jax.Array,
    y: jax.Array,
    bm: int = DEFAULT_BLOCK,
    bn: int = DEFAULT_BLOCK,
    bk: int = DEFAULT_BLOCK,
) -> jax.Array:
    """Pallas tiled matmul, forward only. Shapes must tile evenly."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    nk = k // bk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


@jax.custom_vjp
def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Differentiable Pallas matmul: both fwd and bwd run the L1 kernel,
    so the whole train_step's GEMM FLOPs go through Pallas."""
    return matmul_raw(x, y)


def _matmul_fwd(x, y):
    return matmul_raw(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    # dX = g @ Y^T ; dY = X^T @ g — also tiled Pallas GEMMs.
    return matmul_raw(g, y.T), matmul_raw(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """Analytic VMEM footprint of one grid step (x, y, o tiles resident)."""
    return (bm * bk + bk * bn + bm * bn) * dtype_bytes


def mxu_utilization_estimate(m: int, n: int, k: int, bm: int, bn: int, bk: int) -> float:
    """Fraction of MXU issue slots doing useful work for this tiling:
    ratio of real FLOPs to FLOPs after padding each block to the 128x128x128
    systolic tile. 1.0 when blocks are MXU-aligned."""

    def pad(v: int, t: int = 128) -> int:
        return ((v + t - 1) // t) * t

    real = 2.0 * m * n * k
    padded = 2.0 * pad(bm) * pad(bn) * pad(bk) * (m // bm) * (n // bn) * (k // bk)
    return real / padded if padded else 0.0
