"""Pure-jnp oracles for the L1 Pallas kernels. pytest asserts the kernels
match these to float tolerance across shape/dtype sweeps (hypothesis)."""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def accumulate_ref(acc, g, w):
    return acc + w[0] * g


def sgd_apply_ref(params, acc, scale):
    return params - scale[0] * acc
