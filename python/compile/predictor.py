"""L2: the straggler-prediction LSTM (paper §IV-A).

Each worker predicts its next-iteration received CPU and bandwidth from the
last W observations using an LSTM, then a regression model maps predicted
resources to iteration time (the regression lives in rust, fit online —
rust/src/predict/regressor.rs). Here we build the LSTM:

  * forward pass in pure jnp (lowered to HLO and run from rust via PJRT —
    the prediction path is on the coordinator's hot loop, so it must not
    call python),
  * build-time training on synthetic resource traces shaped like the
    paper's measurements (AR(1) baseline + heavy-tailed contention spikes,
    durations 0.1–500 s, Fig 7), run once by aot.py; trained weights are
    baked into the artifact as constants.

Artifact signature: predictor(history f32[W,2]) -> f32[2]
  history[:, 0] = normalized available CPU, history[:, 1] = normalized bw;
  output = predicted next (cpu, bw).
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

WINDOW = 32
HIDDEN = 16
N_FEATURES = 2


def init_lstm(key: jax.Array) -> Dict[str, jax.Array]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    h, f = HIDDEN, N_FEATURES
    s = 0.3
    return {
        "wx": s * jax.random.normal(k1, (f, 4 * h)),
        "wh": s * jax.random.normal(k2, (h, 4 * h)),
        "b": jnp.zeros((4 * h,)),
        # zero-init output head: with the residual connection the untrained
        # predictor equals the last-value baseline exactly, and training can
        # only learn corrections on top of it.
        "wo": jnp.zeros((h, f)),
        "bo": jnp.zeros((f,)),
        "_k4": jnp.zeros(()) * jnp.sum(k4),  # keep pytree static
    }


def lstm_forward(weights: Dict[str, jax.Array], history: jax.Array) -> jax.Array:
    """history: f32[W, 2] -> predicted next f32[2]."""
    h0 = jnp.zeros((HIDDEN,))
    c0 = jnp.zeros((HIDDEN,))

    def step(carry, x_t):
        h, c = carry
        z = x_t @ weights["wx"] + h @ weights["wh"] + weights["b"]
        i, f, g, o = jnp.split(z, 4)
        i, f, o = jax.nn.sigmoid(i), jax.nn.sigmoid(f + 1.0), jax.nn.sigmoid(o)
        g = jnp.tanh(g)
        c = f * c + i * g
        h = o * jnp.tanh(c)
        return (h, c), None

    (h, _), _ = jax.lax.scan(step, (h0, c0), history)
    # residual head: predict the delta from the last observation, so the
    # untrained model already matches the last-value baseline and training
    # only has to learn the correction (spike decay / AR drift).
    return history[-1] + 0.1 * (h @ weights["wo"] + weights["bo"])


# ---------------------------------------------------------------------------
# Synthetic resource traces (training data)
# ---------------------------------------------------------------------------

def synth_traces(key: jax.Array, n_traces: int, length: int) -> jax.Array:
    """AR(1) utilization + exponential-duration contention spikes, per the
    measurement study: stragglers arise from CPU/bw contention with
    heavy-tailed durations. Returns f32[n, length, 2] in [0, 1]."""
    ks = jax.random.split(key, 6)
    base = jax.random.uniform(ks[0], (n_traces, 1, 2), minval=0.3, maxval=0.9)
    noise = 0.05 * jax.random.normal(ks[1], (n_traces, length, 2))

    def ar1(carry, eps):
        x = 0.9 * carry + eps
        return x, x

    _, wander = jax.lax.scan(ar1, jnp.zeros((n_traces, 2)),
                             jnp.transpose(noise, (1, 0, 2)))
    wander = jnp.transpose(wander, (1, 0, 2))
    # contention spikes: random onset, geometric duration, 30-70% depth
    onset = jax.random.bernoulli(ks[2], 0.03, (n_traces, length, 1))
    depth = jax.random.uniform(ks[3], (n_traces, length, 2), minval=0.3, maxval=0.7)

    def spike_scan(carry, inp):
        on, d = inp
        # spikes decay geometrically (≈ heavy-tailed durations when mixed
        # over random depths) and restart wherever an onset fires
        carry = jnp.maximum(carry * 0.85, on * d)
        return carry, carry

    _, spikes = jax.lax.scan(
        spike_scan, jnp.zeros((n_traces, 2)),
        (jnp.transpose(onset.astype(jnp.float32), (1, 0, 2)),
         jnp.transpose(depth, (1, 0, 2))))
    spikes = jnp.transpose(spikes, (1, 0, 2))
    return jnp.clip(base + wander - spikes, 0.02, 1.0)


def make_dataset(key: jax.Array, n_traces: int = 64, length: int = 256):
    traces = synth_traces(key, n_traces, length)
    xs, ys = [], []
    for start in range(0, length - WINDOW - 1, 7):
        xs.append(traces[:, start:start + WINDOW])
        ys.append(traces[:, start + WINDOW])
    return jnp.concatenate(xs), jnp.concatenate(ys)


def train_lstm(seed: int = 0, steps: int = 300, lr: float = 5e-3,
               n_traces: int = 256) -> Tuple[Dict[str, jax.Array], float]:
    """Adam on MSE over the synthetic dataset. Returns (weights, final mse)."""
    key = jax.random.PRNGKey(seed)
    kw, kd = jax.random.split(key)
    w = init_lstm(kw)
    x, y = make_dataset(kd, n_traces=n_traces)

    def loss_fn(w):
        pred = jax.vmap(lambda h: lstm_forward(w, h))(x)
        return jnp.mean(jnp.square(pred - y))

    # minimal Adam (optax not assumed present)
    m = jax.tree_util.tree_map(jnp.zeros_like, w)
    v = jax.tree_util.tree_map(jnp.zeros_like, w)

    @jax.jit
    def step(w, m, v, t):
        loss, g = jax.value_and_grad(loss_fn)(w)
        m = jax.tree_util.tree_map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree_util.tree_map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        mh = jax.tree_util.tree_map(lambda a: a / (1 - 0.9 ** t), m)
        vh = jax.tree_util.tree_map(lambda a: a / (1 - 0.999 ** t), v)
        w = jax.tree_util.tree_map(
            lambda p, a, b: p - lr * a / (jnp.sqrt(b) + 1e-8), w, mh, vh)
        return w, m, v, loss

    loss = jnp.inf
    for t in range(1, steps + 1):
        w, m, v, loss = step(w, m, v, jnp.float32(t))
    return w, float(loss)


def make_predictor(weights: Dict[str, jax.Array]):
    """Close over trained weights -> artifact fn(history) with baked consts."""
    frozen = jax.tree_util.tree_map(jax.device_get, weights)

    def predict(history):
        return lstm_forward(frozen, history)

    return predict
