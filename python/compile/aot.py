"""AOT: lower the L2 graphs (which embed the L1 Pallas kernels) to HLO TEXT
for the rust runtime.

HLO *text*, never `.serialize()`: jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (behind the `xla` 0.1.6
crate) rejects (`proto.id() <= INT_MAX`). The text parser reassigns ids and
round-trips cleanly — see /opt/xla-example/README.md.

Run via `make artifacts`:
    cd python && python -m compile.aot --out-dir ../artifacts

Outputs per model config C in --configs:
    artifacts/<C>/init.hlo.txt          (seed)                -> params
    artifacts/<C>/train_step.hlo.txt    (params, tokens)      -> (loss, grads)
    artifacts/<C>/eval_loss.hlo.txt     (params, tokens)      -> loss
    artifacts/<C>/apply_update.hlo.txt  (params, acc, scale)  -> params
    artifacts/<C>/grad_acc.hlo.txt      (acc, g, w)           -> acc'
plus artifacts/predictor.hlo.txt (LSTM, §IV-A) and artifacts/manifest.json
describing shapes so the rust side never hard-codes them.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile import predictor as P


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, example_args, path: str) -> int:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def emit_config(cfg: M.ModelConfig, out_dir: str) -> dict:
    cdir = os.path.join(out_dir, cfg.name)
    os.makedirs(cdir, exist_ok=True)
    pp = M.padded_param_count(cfg)
    params_spec = jax.ShapeDtypeStruct((pp,), jnp.float32)
    tokens_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.int32)
    scalar1 = jax.ShapeDtypeStruct((1,), jnp.float32)
    seed_spec = jax.ShapeDtypeStruct((), jnp.int32)

    t0 = time.time()
    sizes = {
        "init": lower_to_file(M.make_init(cfg), (seed_spec,),
                              os.path.join(cdir, "init.hlo.txt")),
        "train_step": lower_to_file(M.make_train_step(cfg),
                                    (params_spec, tokens_spec),
                                    os.path.join(cdir, "train_step.hlo.txt")),
        "eval_loss": lower_to_file(M.make_eval_loss(cfg),
                                   (params_spec, tokens_spec),
                                   os.path.join(cdir, "eval_loss.hlo.txt")),
        "apply_update": lower_to_file(M.make_apply_update(cfg),
                                      (params_spec, params_spec, scalar1),
                                      os.path.join(cdir, "apply_update.hlo.txt")),
        "grad_acc": lower_to_file(M.make_grad_acc(cfg),
                                  (params_spec, params_spec, scalar1),
                                  os.path.join(cdir, "grad_acc.hlo.txt")),
    }
    dt = time.time() - t0
    print(f"[aot] {cfg.name}: params={M.param_count(cfg):,} (padded {pp:,}) "
          f"lowered 5 modules in {dt:.1f}s "
          f"({sum(sizes.values()) / 1e6:.1f} MB HLO text)")
    return {
        "name": cfg.name,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "seq_len": cfg.seq_len,
        "batch": cfg.batch,
        "use_pallas_matmul": cfg.use_pallas_matmul,
        "param_count": M.param_count(cfg),
        "padded_param_count": pp,
        "artifacts": {k: f"{cfg.name}/{k}.hlo.txt" for k in sizes},
        "hlo_bytes": sizes,
    }


def emit_predictor(out_dir: str) -> dict:
    t0 = time.time()
    weights, mse = P.train_lstm(seed=0, steps=200)
    fn = P.make_predictor(weights)
    hist_spec = jax.ShapeDtypeStruct((P.WINDOW, P.N_FEATURES), jnp.float32)
    n = lower_to_file(fn, (hist_spec,), os.path.join(out_dir, "predictor.hlo.txt"))
    print(f"[aot] predictor: trained LSTM (mse={mse:.5f}) in "
          f"{time.time() - t0:.1f}s, {n / 1e3:.0f} KB HLO")
    return {
        "window": P.WINDOW,
        "features": P.N_FEATURES,
        "hidden": P.HIDDEN,
        "train_mse": mse,
        "artifact": "predictor.hlo.txt",
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small,base",
                    help="comma list from: " + ",".join(M.CONFIGS))
    ap.add_argument("--skip-predictor", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": 1, "interchange": "hlo-text", "configs": {}}
    for name in args.configs.split(","):
        cfg = M.CONFIGS[name.strip()]
        manifest["configs"][cfg.name] = emit_config(cfg, args.out_dir)
    if not args.skip_predictor:
        manifest["predictor"] = emit_predictor(args.out_dir)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
