"""L2: JAX transformer language model — the per-worker compute of STAR.

The paper's workers run PyTorch CNN/LSTM/Transformer jobs on A100s; here
the per-worker train step is a decoder-only transformer LM whose GEMMs run
through the L1 Pallas kernel (kernels.matmul) and whose optimizer apply
runs through the fused L1 gradagg kernel. Everything is AOT-lowered by
aot.py to HLO text and executed from the rust coordinator via PJRT —
python never touches the request path.

Design choices that matter to the rust side:
  * Parameters live as ONE flat f32[P] vector (padded to a block multiple)
    so the coordinator handles a single device buffer, and x-order
    aggregation is a 1-D kernel over the whole model.
  * Layers are stacked + scanned (jax.lax.scan) so the lowered HLO size is
    O(1) in depth.
  * Artifacts per config:
        init        : (seed i32[])                       -> f32[P]
        train_step  : (params f32[P], tokens i32[B,T+1]) -> (loss f32[], grads f32[P])
        apply_update: (params f32[P], acc f32[P], scale f32[1]) -> f32[P]
        grad_acc    : (acc f32[P], g f32[P], w f32[1])   -> f32[P]
        eval_loss   : (params f32[P], tokens i32[B,T+1]) -> f32[]
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import gradagg
from compile.kernels import matmul as pmm

PAD_MULTIPLE = 4096  # flat param vector padded so 1-D kernels tile evenly


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer LM configuration."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq_len: int  # tokens per training sample (inputs; +1 token for target)
    batch: int
    use_pallas_matmul: bool = True

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model


# Named configs. `tiny` exercises the full Pallas path cheaply (tests,
# quickstart); `base` is the e2e training default; `gpt100m` is the
# ~100M-parameter config from the task spec (pallas matmul disabled there:
# interpret-mode pallas is a CPU-numpy emulator and would make a 100M-param
# CPU run intractable — the kernel is still validated end-to-end through
# PJRT by the smaller configs; see DESIGN.md §2).
CONFIGS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        ModelConfig("tiny", vocab=512, d_model=64, n_layers=2, n_heads=2,
                    seq_len=32, batch=4, use_pallas_matmul=True),
        ModelConfig("small", vocab=2048, d_model=128, n_layers=2, n_heads=4,
                    seq_len=64, batch=4, use_pallas_matmul=True),
        ModelConfig("base", vocab=8192, d_model=256, n_layers=4, n_heads=8,
                    seq_len=128, batch=4, use_pallas_matmul=False),
        ModelConfig("gpt100m", vocab=32768, d_model=768, n_layers=12,
                    n_heads=12, seq_len=256, batch=4, use_pallas_matmul=False),
    ]
}


def _mm(cfg: ModelConfig, x: jax.Array, y: jax.Array) -> jax.Array:
    """2-D GEMM through the Pallas kernel (or XLA-native for big configs)."""
    if cfg.use_pallas_matmul:
        return pmm.matmul(x, y)
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Parameter layout
# ---------------------------------------------------------------------------

def param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    """Ordered parameter tree (dict order == flat layout order)."""
    d, f, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    return {
        "tok_emb": (cfg.vocab, d),
        "pos_emb": (cfg.seq_len, d),
        # per-layer tensors stacked on a leading L axis for lax.scan
        "ln1_g": (L, d),
        "ln1_b": (L, d),
        "attn_qkv": (L, d, 3 * d),
        "attn_out": (L, d, d),
        "ln2_g": (L, d),
        "ln2_b": (L, d),
        "mlp_in": (L, d, f),
        "mlp_in_b": (L, f),
        "mlp_out": (L, f, d),
        "mlp_out_b": (L, d),
        "lnf_g": (d,),
        "lnf_b": (d,),
        "head": (d, cfg.vocab),
    }


def param_count(cfg: ModelConfig) -> int:
    import math

    return sum(math.prod(s) for s in param_shapes(cfg).values())


def padded_param_count(cfg: ModelConfig) -> int:
    n = param_count(cfg)
    return ((n + PAD_MULTIPLE - 1) // PAD_MULTIPLE) * PAD_MULTIPLE


def unflatten(cfg: ModelConfig, flat: jax.Array) -> Dict[str, jax.Array]:
    out, off = {}, 0
    for name, shp in param_shapes(cfg).items():
        n = 1
        for s in shp:
            n *= s
        out[name] = jax.lax.dynamic_slice(flat, (off,), (n,)).reshape(shp)
        off += n
    return out


def flatten(cfg: ModelConfig, tree: Dict[str, jax.Array]) -> jax.Array:
    parts = [tree[name].reshape(-1) for name in param_shapes(cfg)]
    flat = jnp.concatenate(parts)
    pad = padded_param_count(cfg) - flat.shape[0]
    return jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])


def init_params(cfg: ModelConfig, seed: jax.Array) -> jax.Array:
    """Flat-initialized parameters from an int32 seed (AOT artifact)."""
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    tree = {}
    for name, shp in param_shapes(cfg).items():
        key, sub = jax.random.split(key)
        fan_in = shp[-2] if len(shp) >= 2 else shp[-1]
        if name.endswith(("_b", "_g")) or name in ("lnf_b",):
            tree[name] = (jnp.ones(shp, jnp.float32) if name.endswith("_g")
                          else jnp.zeros(shp, jnp.float32))
        else:
            scale = 0.02 if "emb" in name else (1.0 / jnp.sqrt(fan_in))
            tree[name] = scale * jax.random.normal(sub, shp, jnp.float32)
    return flatten(cfg, tree)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _layernorm(x: jax.Array, g: jax.Array, b: jax.Array) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + b


def _attention(cfg: ModelConfig, x: jax.Array, qkv_w, out_w) -> jax.Array:
    """Causal multi-head self-attention. x: [B, T, d]."""
    B, T, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    qkv = _mm(cfg, x.reshape(B * T, d), qkv_w).reshape(B, T, 3, h, dh)
    q = qkv[:, :, 0].transpose(0, 2, 1, 3)  # [B, h, T, dh]
    k = qkv[:, :, 1].transpose(0, 2, 1, 3)
    v = qkv[:, :, 2].transpose(0, 2, 1, 3)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(dh))
    mask = jnp.tril(jnp.ones((T, T), jnp.bool_))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    y = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    y = y.transpose(0, 2, 1, 3).reshape(B * T, d)
    return _mm(cfg, y, out_w).reshape(B, T, d)


def _block(cfg: ModelConfig, x: jax.Array, lp) -> jax.Array:
    x = x + _attention(cfg, _layernorm(x, lp["ln1_g"], lp["ln1_b"]),
                       lp["attn_qkv"], lp["attn_out"])
    h = _layernorm(x, lp["ln2_g"], lp["ln2_b"])
    B, T, d = h.shape
    h = _mm(cfg, h.reshape(B * T, d), lp["mlp_in"]) + lp["mlp_in_b"]
    h = jax.nn.gelu(h)
    h = _mm(cfg, h, lp["mlp_out"]) + lp["mlp_out_b"]
    return x + h.reshape(B, T, d)


def forward_loss(cfg: ModelConfig, flat_params: jax.Array, tokens: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy. tokens: i32[B, T+1]."""
    p = unflatten(cfg, flat_params)
    x_tok = tokens[:, :-1]
    y_tok = tokens[:, 1:]
    B, T = x_tok.shape
    x = p["tok_emb"][x_tok] + p["pos_emb"][None, :T]

    layer_names = ["ln1_g", "ln1_b", "attn_qkv", "attn_out",
                   "ln2_g", "ln2_b", "mlp_in", "mlp_in_b",
                   "mlp_out", "mlp_out_b"]
    stacked = {k: p[k] for k in layer_names}

    def body(carry, lp):
        return _block(cfg, carry, lp), None

    x, _ = jax.lax.scan(body, x, stacked)
    x = _layernorm(x, p["lnf_g"], p["lnf_b"])
    logits = _mm(cfg, x.reshape(B * T, cfg.d_model), p["head"])
    logits = logits.reshape(B, T, cfg.vocab)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y_tok[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# ---------------------------------------------------------------------------
# AOT entry points
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig):
    def train_step(flat_params, tokens):
        loss, grads = jax.value_and_grad(
            lambda fp: forward_loss(cfg, fp, tokens))(flat_params)
        return loss, grads

    return train_step


def make_eval_loss(cfg: ModelConfig):
    def eval_loss(flat_params, tokens):
        return forward_loss(cfg, flat_params, tokens)

    return eval_loss


def make_apply_update(cfg: ModelConfig):
    def apply_update(flat_params, acc, scale):
        # Fused L1 kernel: p - scale*acc, scale = lr / num_reports.
        return gradagg.sgd_apply(flat_params, acc, scale)

    return apply_update


def make_grad_acc(cfg: ModelConfig):
    def grad_acc(acc, g, w):
        return gradagg.accumulate(acc, g, w)

    return grad_acc


def make_init(cfg: ModelConfig):
    def init(seed):
        return init_params(cfg, seed)

    return init
