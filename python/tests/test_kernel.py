"""L1 correctness: Pallas kernels vs pure-jnp oracle (ref.py).

hypothesis sweeps shapes (and weight/scale magnitudes); this is the core
correctness signal for the compute hot path before AOT lowering.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gradagg, ref
from compile.kernels import matmul as pmm

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([1, 2, 8, 16, 64, 128, 160]),
    k=st.sampled_from([1, 4, 16, 64, 128]),
    n=st.sampled_from([1, 8, 32, 64, 128, 192]),
)
def test_matmul_matches_ref(m, k, n):
    x, y = rand(m * 1000 + k, m, k), rand(n * 1000 + k + 1, k, n)
    got = pmm.matmul_raw(x, y)
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([16, 64, 96]),
    k=st.sampled_from([16, 32, 64]),
    n=st.sampled_from([16, 48, 64]),
    bm=st.sampled_from([8, 16, 128]),
)
def test_matmul_block_shapes(m, k, n, bm):
    """Non-default block sizes (incl. ones larger than the dims) agree."""
    x, y = rand(1, m, k), rand(2, k, n)
    got = pmm.matmul_raw(x, y, bm=bm, bn=bm, bk=bm)
    np.testing.assert_allclose(got, ref.matmul_ref(x, y), rtol=1e-5, atol=1e-5)


def test_matmul_grad_matches_jnp():
    x, y = rand(3, 32, 16), rand(4, 16, 24)

    def f_pallas(x, y):
        return jnp.sum(jnp.sin(pmm.matmul(x, y)))

    def f_ref(x, y):
        return jnp.sum(jnp.sin(ref.matmul_ref(x, y)))

    gx_p, gy_p = jax.grad(f_pallas, argnums=(0, 1))(x, y)
    gx_r, gy_r = jax.grad(f_ref, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gx_p, gx_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gy_p, gy_r, rtol=1e-4, atol=1e-5)


def test_matmul_nonsquare_tall_skinny():
    x, y = rand(5, 512, 8), rand(6, 8, 256)
    np.testing.assert_allclose(
        pmm.matmul_raw(x, y), ref.matmul_ref(x, y), rtol=1e-5, atol=1e-5)


def test_vmem_estimate_default_blocks_under_budget():
    assert pmm.vmem_bytes(128, 128, 128) < 16 * 1024 * 1024


def test_mxu_utilization_aligned_is_one():
    assert pmm.mxu_utilization_estimate(256, 256, 256, 128, 128, 128) == pytest.approx(1.0)
    assert pmm.mxu_utilization_estimate(256, 256, 256, 64, 64, 64) < 0.2


# ---------------------------------------------------------------------------
# gradagg
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    p=st.sampled_from([1, 7, 64, 1024, 4096, 65536, 65536 * 2 + 4096]),
    w=st.floats(min_value=-3.0, max_value=3.0, allow_nan=False),
)
def test_accumulate_matches_ref(p, w):
    acc, g = rand(p, p), rand(p + 1, p)
    wv = jnp.array([w], jnp.float32)
    np.testing.assert_allclose(
        gradagg.accumulate(acc, g, wv), ref.accumulate_ref(acc, g, wv),
        rtol=1e-6, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    p=st.sampled_from([1, 16, 4096, 65536, 65536 + 12288]),
    scale=st.floats(min_value=0.0, max_value=1.0, allow_nan=False),
)
def test_sgd_apply_matches_ref(p, scale):
    params, acc = rand(2 * p + 1, p), rand(2 * p + 2, p)
    sv = jnp.array([scale], jnp.float32)
    np.testing.assert_allclose(
        gradagg.sgd_apply(params, acc, sv), ref.sgd_apply_ref(params, acc, sv),
        rtol=1e-6, atol=1e-6)


def test_xorder_update_composition():
    """x-order update == ref mean-gradient SGD: accumulate x grads then
    apply with scale=lr/x (exactly how the rust coordinator uses it)."""
    p, x_reports, lr = 4096, 3, 0.1
    params = rand(0, p)
    grads = [rand(i + 10, p) for i in range(x_reports)]
    acc = jnp.zeros((p,), jnp.float32)
    one = jnp.array([1.0], jnp.float32)
    for g in grads:
        acc = gradagg.accumulate(acc, g, one)
    new = gradagg.sgd_apply(params, acc, jnp.array([lr / x_reports], jnp.float32))
    want = params - lr * sum(grads) / x_reports
    np.testing.assert_allclose(new, want, rtol=1e-5, atol=1e-6)


def test_fused_hbm_traffic_beats_naive():
    for x in (1, 2, 4, 8):
        assert gradagg.hbm_traffic_bytes_fused(10**6, x) < gradagg.hbm_traffic_bytes_naive(10**6, x)
