"""AOT path: tiny config lowers to parseable HLO text + manifest schema."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")


def test_hlo_text_header(tmp_path):
    cfg = M.CONFIGS["tiny"]
    pp = M.padded_param_count(cfg)
    path = tmp_path / "apply.hlo.txt"
    n = aot.lower_to_file(
        M.make_apply_update(cfg),
        (jax.ShapeDtypeStruct((pp,), jnp.float32),
         jax.ShapeDtypeStruct((pp,), jnp.float32),
         jax.ShapeDtypeStruct((1,), jnp.float32)),
        str(path))
    text = path.read_text()
    assert n == len(text) > 0
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # pallas interpret-mode must lower to plain HLO: no Mosaic custom-calls
    assert "mosaic" not in text.lower()


def test_emit_config_manifest_fields(tmp_path):
    cfg = M.CONFIGS["tiny"]
    entry = aot.emit_config(cfg, str(tmp_path))
    for key in ("param_count", "padded_param_count", "artifacts", "vocab",
                "seq_len", "batch"):
        assert key in entry
    for name, rel in entry["artifacts"].items():
        p = tmp_path / rel
        assert p.exists() and p.stat().st_size > 0, name
    assert entry["padded_param_count"] % M.PAD_MULTIPLE == 0


def test_repo_artifacts_manifest_if_built():
    """If `make artifacts` has run, the manifest must be consistent."""
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man = os.path.join(root, "manifest.json")
    if not os.path.exists(man):
        pytest.skip("artifacts not built yet")
    m = json.load(open(man))
    assert m["interchange"] == "hlo-text"
    for cfg in m["configs"].values():
        for rel in cfg["artifacts"].values():
            assert os.path.exists(os.path.join(root, rel)), rel
