"""L2 correctness: transformer LM shapes, gradients, and trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.CONFIGS["tiny"]


def tokens_for(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    return jax.random.randint(key, (cfg.batch, cfg.seq_len + 1), 0, cfg.vocab, jnp.int32)


@pytest.fixture(scope="module")
def params():
    return M.make_init(CFG)(jnp.int32(0))


def test_param_count_positive_and_padded():
    n, pp = M.param_count(CFG), M.padded_param_count(CFG)
    assert 0 < n <= pp and pp % M.PAD_MULTIPLE == 0


def test_flatten_unflatten_roundtrip(params):
    tree = M.unflatten(CFG, params)
    again = M.flatten(CFG, tree)
    np.testing.assert_allclose(params, again)
    assert set(tree) == set(M.param_shapes(CFG))
    for k, s in M.param_shapes(CFG).items():
        assert tree[k].shape == s


def test_init_deterministic_and_seed_sensitive():
    a = M.make_init(CFG)(jnp.int32(7))
    b = M.make_init(CFG)(jnp.int32(7))
    c = M.make_init(CFG)(jnp.int32(8))
    np.testing.assert_array_equal(a, b)
    assert not np.allclose(a, c)


def test_loss_finite_and_near_uniform_at_init(params):
    loss = M.make_eval_loss(CFG)(params, tokens_for(CFG))
    assert np.isfinite(loss)
    # at init, next-token CE should be near ln(vocab)
    assert abs(float(loss) - np.log(CFG.vocab)) < 1.5


def test_train_step_grad_shapes(params):
    loss, grads = M.make_train_step(CFG)(params, tokens_for(CFG))
    assert grads.shape == params.shape
    assert np.isfinite(loss) and np.isfinite(np.sum(grads))
    # padding region must receive zero gradient
    n = M.param_count(CFG)
    np.testing.assert_array_equal(np.asarray(grads)[n:], 0.0)


def test_apply_update_moves_against_gradient(params):
    step = M.make_train_step(CFG)
    apply_u = M.make_apply_update(CFG)
    toks = tokens_for(CFG)
    loss0, grads = step(params, toks)
    new = apply_u(params, grads, jnp.array([0.5], jnp.float32))
    loss1, _ = step(new, toks)
    assert float(loss1) < float(loss0)


def test_sgd_loop_decreases_loss(params):
    """A few real SGD steps on a fixed batch must reduce loss materially —
    the same loop the rust coordinator runs through PJRT."""
    step = M.make_train_step(CFG)
    apply_u = M.make_apply_update(CFG)
    toks = tokens_for(CFG, seed=3)
    p = params
    losses = []
    for _ in range(5):
        loss, g = step(p, toks)
        losses.append(float(loss))
        p = apply_u(p, g, jnp.array([0.5], jnp.float32))
    assert losses[-1] < losses[0] - 0.05, losses


def test_grad_acc_weighted_mean(params):
    acc_fn = M.make_grad_acc(CFG)
    g1 = jnp.ones_like(params)
    g2 = 3.0 * jnp.ones_like(params)
    acc = jnp.zeros_like(params)
    acc = acc_fn(acc, g1, jnp.array([1.0], jnp.float32))
    acc = acc_fn(acc, g2, jnp.array([1.0], jnp.float32))
    np.testing.assert_allclose(acc, 4.0 * np.ones_like(params), rtol=1e-6)


def test_forward_is_causal(params):
    """Changing a future token must not change earlier positions' loss
    contributions — verified via per-position logits."""
    p = M.unflatten(CFG, params)
    toks = tokens_for(CFG)

    def logits_at(tokens):
        x_tok = tokens[:, :-1]
        B, T = x_tok.shape
        x = p["tok_emb"][x_tok] + p["pos_emb"][None, :T]
        names = ["ln1_g", "ln1_b", "attn_qkv", "attn_out", "ln2_g", "ln2_b",
                 "mlp_in", "mlp_in_b", "mlp_out", "mlp_out_b"]
        stacked = {k: p[k] for k in names}
        x, _ = jax.lax.scan(lambda c, lp: (M._block(CFG, c, lp), None), x, stacked)
        return x

    a = logits_at(toks)
    toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % CFG.vocab)
    b = logits_at(toks2)
    # last input position changed => positions 0..T-2 identical
    np.testing.assert_allclose(a[:, :-1], b[:, :-1], rtol=1e-6, atol=1e-6)
