"""Straggler-prediction LSTM (§IV-A): shape, trainability, and that it
beats the naive last-value predictor on held-out synthetic traces."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import predictor as P

jax.config.update("jax_platform_name", "cpu")


def test_forward_shape_and_range():
    w = P.init_lstm(jax.random.PRNGKey(0))
    h = jnp.ones((P.WINDOW, P.N_FEATURES)) * 0.5
    out = P.lstm_forward(w, h)
    assert out.shape == (P.N_FEATURES,)
    assert np.all(np.isfinite(out))


def test_synth_traces_in_unit_range():
    tr = P.synth_traces(jax.random.PRNGKey(1), 8, 128)
    assert tr.shape == (8, 128, 2)
    assert float(jnp.min(tr)) >= 0.0 and float(jnp.max(tr)) <= 1.0


def test_training_reduces_mse():
    # compare on the same training distribution: more steps => lower mse
    _, mse_short = P.train_lstm(seed=0, steps=5, n_traces=64)
    _, mse_long = P.train_lstm(seed=0, steps=120, n_traces=64)
    assert mse_long < mse_short


def test_beats_last_value_baseline():
    w, _ = P.train_lstm(seed=0, steps=200)
    x, y = P.make_dataset(jax.random.PRNGKey(99), n_traces=16, length=128)
    pred = jax.vmap(lambda h: P.lstm_forward(w, h))(x)
    mse_lstm = float(jnp.mean(jnp.square(pred - y)))
    mse_last = float(jnp.mean(jnp.square(x[:, -1] - y)))
    assert mse_lstm < mse_last, (mse_lstm, mse_last)
