//! All-reduce scenario: STAR's AR-ring modes (§IV-B) on a straggling
//! 8-worker ring — shows the remove-x-stragglers + parent-wait trade and
//! the Eq. (3) heuristic's pick, then validates against the simulator.
//!
//! Run: `cargo run --release --example ar_ring -- [--workers 8] [--seed 0]`

use star::cli::Args;
use star::decide::{choose_ar_heuristic, time_to_progress_ar};
use star::driver::{Driver, DriverConfig, DriverMode};
use star::models::ZOO;
use star::sync::SyncMode;
use star::table::{self, Table};
use star::trace::{Arch, JobSpec};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> star::Result<()> {
    let args = Args::parse_env();
    args.check_known(&["workers", "seed"])?;
    let n = args.usize_or("workers", 8)?;
    let seed = args.u64_or("seed", 0)?;
    let spec = &ZOO[4]; // DenseNet121

    // a ring with one severe and one mild straggler
    let mut predicted = vec![0.45; n];
    predicted[0] = 1.8;
    predicted[1] = 0.62;

    println!("Eq. (3) landscape (time to unit progress, s):");
    let mut t = Table::new("", &["x_removed", "tw=30ms", "tw=90ms", "tw=150ms", "tw=210ms"]);
    for x in 0..=2usize {
        let mut row = vec![table::s(format!("{x}"))];
        for tw in [30.0, 90.0, 150.0, 210.0] {
            row.push(table::f(time_to_progress_ar(spec, 100.0, n, x, tw, &predicted), 3));
        }
        t.rowf(&row);
    }
    t.print();

    let d = choose_ar_heuristic(spec, 100.0, n, 2, &star::star::TW_GRID_MS, &predicted);
    println!("\nSTAR-H picks: {} (est {:.3})\n", d.mode.name(), d.est);

    // validate in the simulator: chosen mode vs full ring
    let mk_fixed = |mode: SyncMode| -> star::driver::PolicyFactory {
        Box::new(move |_| {
            Box::new(star::exp::measure::Fixed {
                mode: DriverMode::Sync(mode),
                rescaled: true,
                label: "ring",
            })
        })
    };
    let mut t2 = Table::new("simulated outcome (one job, straggling worker 1)", &[
        "mode", "TTA_s", "JCT_s", "acc_%",
    ]);
    let chosen_name = d.mode.name();
    for (label, mode) in [
        ("full ring".to_string(), SyncMode::ArRing { removed: 0, tw_ms: 0.0 }),
        (chosen_name, d.mode),
    ] {
        let mut cfg = DriverConfig {
            arch: Arch::AllReduce,
            seed,
            record_series: false,
            ..Default::default()
        };
        cfg.throttles.push((0, 1, 0.3, 0.6));
        let specs = vec![JobSpec {
            id: 0,
            arrival_s: 0.0,
            model: 4,
            workers: n,
            ps_count: 1,
            ps_on_gpu_servers: false,
        }];
        let (stats, _) = Driver::new(cfg, specs, mk_fixed(mode)).run();
        let s = &stats[0];
        t2.rowf(&[
            table::s(label),
            match s.tta_s {
                Some(v) => table::f(v, 0),
                None => table::s(">cap"),
            },
            table::f(s.jct_s, 0),
            table::f(s.converged_value, 2),
        ]);
    }
    t2.print();
    Ok(())
}
