//! End-to-end driver: proves the three layers compose on a real workload.
//!
//! N in-process workers train a transformer LM on a synthetic zipf corpus
//! through the AOT artifacts (L2 JAX graph embedding the L1 Pallas
//! kernels, executed via PJRT from this L3 coordinator). Worker slowness
//! is injected from the same heavy-tailed contention model the simulator
//! uses; each round STAR predicts per-worker times, picks a
//! synchronization mode (SSGD / ASGD / static-x / dynamic-x), rescales the
//! LR, and the update is applied through the fused grad-acc + SGD-apply
//! Pallas kernels. The loss curve and mode decisions are logged (and the
//! run is recorded in EXPERIMENTS.md).
//!
//! Run: `cargo run --release --example e2e_train -- [--config base]
//!       [--workers 4] [--steps 200] [--mode star|ssgd|asgd|static-2]
//!       [--seed 0] [--log results/e2e_loss.csv]`

use std::time::Instant;

use star::cli::Args;
use star::decide::{choose_ps_heuristic, expected_reports};
use star::predict::{straggler_flags, History};
use star::runtime::{Manifest, Runtime, TrainSession};
use star::simrng::Rng;
use star::sync::SyncMode;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> star::Result<()> {
    let args = Args::parse_env();
    args.check_known(&["config", "workers", "steps", "mode", "seed", "lr", "log"])?;
    let config = args.str_or("config", "base");
    let n = args.usize_or("workers", 4)?;
    let steps = args.u64_or("steps", 200)?;
    let mode_arg = args.str_or("mode", "star");
    let seed = args.u64_or("seed", 0)?;
    let base_lr = args.f64_or("lr", 0.5)? as f32;
    let log_path = args.str_or("log", "results/e2e_loss.csv");

    let man = Manifest::discover()?;
    let rt = Runtime::cpu()?;
    let mut session = TrainSession::new(&rt, &man, &config)?;
    session.init_params(seed as i32)?;
    let info = session.info.clone();
    println!(
        "e2e_train: config={config} ({} params, vocab {}, seq {}, batch {}/worker), \
         {n} workers, {steps} steps, mode={mode_arg}",
        info.param_count, info.vocab, info.seq_len, info.batch
    );

    // synthetic zipf corpus (per-worker shards via distinct streams)
    let mut worker_rngs: Vec<Rng> = (0..n).map(|w| Rng::new(seed, 100 + w as u64)).collect();
    let mut batch = |w: usize| -> Vec<i32> {
        star::runtime::synth_corpus_batch(&info, &mut worker_rngs[w])
    };

    // injected contention: per-worker heavy-tailed slowdown factors from
    // the simulator's interference model (worker 0 occasionally severe)
    let mut contention = Rng::new(seed, 7);
    let mut slowdown = vec![1.0f64; n];
    let mut slow_until = vec![0.0f64; n];

    // STAR state: per-worker history + predicted times
    let mut histories: Vec<History> = (0..n).map(|_| History::new()).collect();
    let mut last_times = vec![0.5f64; n];
    let spec = &star::models::ZOO[9]; // Transformer row of the zoo

    let mut held_out = batch(0);
    held_out.rotate_left(7);
    let mut log = String::from("step,time_s,mode,loss,eval_loss,stragglers\n");
    let t0 = Instant::now();
    let mut mode_counts: std::collections::BTreeMap<String, u64> = Default::default();

    for step in 0..steps {
        // -- contention evolution ---------------------------------------
        let now = t0.elapsed().as_secs_f64();
        for w in 0..n {
            if now >= slow_until[w] {
                slowdown[w] = 1.0;
                if contention.chance(0.08) {
                    slowdown[w] = contention.range(1.5, 4.0);
                    slow_until[w] = now + contention.lognormal(0.5, 1.0).clamp(0.1, 60.0);
                }
            }
        }

        // -- per-worker gradient computation (real PJRT execution) -------
        let mut grads: Vec<Vec<f32>> = Vec::with_capacity(n);
        let mut losses = Vec::with_capacity(n);
        let mut times = Vec::with_capacity(n);
        for w in 0..n {
            let toks = batch(w);
            let t = Instant::now();
            let (loss, g) = session.train_step(&toks)?;
            let real = t.elapsed().as_secs_f64();
            let simulated = real * slowdown[w];
            times.push(simulated);
            losses.push(loss);
            grads.push(g);
            histories[w].push(1.0 / slowdown[w], 1.0 / slowdown[w], simulated);
            last_times[w] = simulated;
        }

        // -- STAR decision ------------------------------------------------
        let predicted: Vec<f64> = last_times.clone();
        let flags = straggler_flags(&predicted);
        let stragglers = flags.iter().filter(|&&f| f).count();
        let mode = match mode_arg.as_str() {
            "ssgd" => SyncMode::Ssgd,
            "asgd" => SyncMode::Asgd,
            m if m.starts_with("static-") => {
                SyncMode::StaticX(m[7..].parse().unwrap_or(n.max(2) - 1))
            }
            "dynamic" => SyncMode::DynamicX,
            _ => {
                if stragglers == 0 {
                    SyncMode::Ssgd
                } else {
                    choose_ps_heuristic(spec, step as f64, n, &predicted).mode
                }
            }
        };
        *mode_counts.entry(mode.name()).or_insert(0) += 1;

        // -- apply per the mode's round plan (fused Pallas kernels) -------
        let plan = star::sync::plan_round(&mode, &times, &predicted);
        let mut applied = 0usize;
        for update in &plan.updates {
            let group: Vec<Vec<f32>> =
                update.members.iter().map(|&w| grads[w].clone()).collect();
            let reports = group.len();
            let lr = base_lr * reports as f32 / n as f32; // §IV-C LR scaling
            session.xorder_update(&group, lr)?;
            applied += reports;
        }
        debug_assert_eq!(applied, plan.reports_used);

        let mean_loss = losses.iter().sum::<f32>() / n as f32;
        if step % 10 == 0 || step + 1 == steps {
            let eval = session.eval_loss(&held_out)?;
            println!(
                "step {step:>4}  mode {:<9}  train {mean_loss:.4}  eval {eval:.4}  \
                 stragglers {stragglers}  ({:.1}s)",
                mode.name(),
                t0.elapsed().as_secs_f64()
            );
            log.push_str(&format!(
                "{step},{:.2},{},{mean_loss:.5},{eval:.5},{stragglers}\n",
                t0.elapsed().as_secs_f64(),
                mode.name()
            ));
        }
    }

    let eval = session.eval_loss(&held_out)?;
    println!(
        "\ndone in {:.1}s — final eval loss {eval:.4} (init ≈ ln V = {:.2})",
        t0.elapsed().as_secs_f64(),
        (info.vocab as f32).ln()
    );
    println!("mode usage: {mode_counts:?}");
    if let Some(dir) = std::path::Path::new(&log_path).parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    std::fs::write(&log_path, log)?;
    println!("loss curve written to {log_path}");
    Ok(())
}
