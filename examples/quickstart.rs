//! Quickstart: the smallest end-to-end tour of the STAR public API.
//!
//! 1. load the AOT artifacts (built once by `make artifacts`) and run a
//!    few *real* training steps of the tiny transformer through PJRT —
//!    the same Pallas-kernel compute path the coordinator uses;
//! 2. run STAR's straggler prediction + mode determination on a toy
//!    observation;
//! 3. simulate a handful of trace jobs under STAR-H vs SSGD.
//!
//! Run: `cargo run --release --example quickstart`

use star::baselines::make_policy;
use star::decide::choose_ps_heuristic;
use star::driver::{Driver, DriverConfig};
use star::models::ZOO;
use star::runtime::{Manifest, Runtime, TrainSession};
use star::simrng::Rng;
use star::trace::{generate, TraceConfig};

fn main() -> star::Result<()> {
    // ---- 1. real compute through the AOT artifacts ----------------------
    match Manifest::discover() {
        Ok(man) => {
            let rt = Runtime::cpu()?;
            let mut session = TrainSession::new(&rt, &man, "tiny")?;
            session.init_params(0)?;
            let mut rng = Rng::seeded(1);
            let info = session.info.clone();
            println!(
                "tiny transformer: {} params (Pallas matmul: {})",
                info.param_count, info.use_pallas_matmul
            );
            let batch =
                |rng: &mut Rng| -> Vec<i32> { star::runtime::synth_corpus_batch(&info, rng) };
            for step in 0..5 {
                let toks = batch(&mut rng);
                let (loss, grads) = session.train_step(&toks)?;
                session.xorder_update(&[grads], 0.5)?;
                println!("  step {step}: loss {loss:.4}");
            }
        }
        Err(e) => println!("(skipping PJRT demo: {e})"),
    }

    // ---- 2. one STAR decision ------------------------------------------
    let spec = &ZOO[4]; // DenseNet121
    let predicted = vec![0.42, 0.40, 0.43, 0.41, 0.44, 0.45, 0.43, 1.9]; // one straggler
    let d = choose_ps_heuristic(spec, 100.0, 8, &predicted);
    println!(
        "\nSTAR-H decision for a straggling {}: {} (est {:.3}s/progress, LR {:.4})",
        spec.name,
        d.mode.name(),
        d.est,
        d.lr
    );

    // ---- 3. STAR vs SSGD on a small trace --------------------------------
    for sys in ["SSGD", "STAR-H"] {
        let trace = generate(&TraceConfig { jobs: 6, span_s: 1200.0, ..Default::default() });
        let cfg = DriverConfig { record_series: false, ..Default::default() };
        let name = sys.to_string();
        let (stats, _) =
            Driver::new(cfg, trace, Box::new(move |_| make_policy(&name).expect("known system"))).run();
        let tta: Vec<f64> = stats.iter().filter_map(|s| s.tta_s).collect();
        println!(
            "{sys:<8} mean TTA {:>6.0}s  mean JCT {:>6.0}s  ({} jobs)",
            tta.iter().sum::<f64>() / tta.len().max(1) as f64,
            stats.iter().map(|s| s.jct_s).sum::<f64>() / stats.len() as f64,
            stats.len()
        );
    }
    Ok(())
}
