//! Trace replay: run the full §V comparison on a Philly-style trace —
//! either generated (default) or parsed from a CSV
//! (`--trace file.csv`, rows `jobid,submit_s,num_gpus[,model]`).
//!
//! Run: `cargo run --release --example trace_replay -- [--jobs 40]
//!       [--arch ps|ar] [--seed 0] [--trace file.csv]`

use star::baselines::make_policy;
use star::cli::Args;
use star::driver::{Driver, DriverConfig};
use star::stats;
use star::table::{self, Table};
use star::trace::{generate, parse_philly_csv, Arch, TraceConfig};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> star::Result<()> {
    let args = Args::parse_env();
    args.check_known(&["jobs", "arch", "seed", "trace"])?;
    let jobs = args.usize_or("jobs", 40)?;
    let seed = args.u64_or("seed", 0)?;
    let arch = match args.str_or("arch", "ps").as_str() {
        "ar" => Arch::AllReduce,
        _ => Arch::Ps,
    };
    let tc = TraceConfig { jobs, seed, span_s: jobs as f64 * 280.0, ..Default::default() };
    let trace = match args.get("trace") {
        Some(path) => parse_philly_csv(&std::fs::read_to_string(path)?, &tc)?,
        None => generate(&tc),
    };

    let systems: Vec<&str> = match arch {
        Arch::Ps => vec!["SSGD", "ASGD", "Sync-Switch", "LB-BSP", "LGC", "Zeno++", "STAR-H", "STAR-ML"],
        Arch::AllReduce => vec!["SSGD", "LB-BSP", "LGC", "STAR-H", "STAR-ML"],
    };
    let mut t = Table::new(
        &format!("trace replay: {} jobs, {arch:?}", trace.len()),
        &["system", "TTA_mean_s", "JCT_mean_s", "acc_%", "ppl", "stragglers", "reached"],
    );
    for sys in systems {
        let cfg = DriverConfig { arch, seed, record_series: false, ..Default::default() };
        let name = sys.to_string();
        let (stats_v, _) =
            Driver::new(cfg, trace.clone(), Box::new(move |_| make_policy(&name).expect("known system"))).run();
        let tta: Vec<f64> = stats_v.iter().filter_map(|s| s.tta_s).collect();
        let jct: Vec<f64> = stats_v.iter().map(|s| s.jct_s).collect();
        let acc: Vec<f64> =
            stats_v.iter().filter(|s| !s.is_nlp).map(|s| s.converged_value).collect();
        let ppl: Vec<f64> =
            stats_v.iter().filter(|s| s.is_nlp).map(|s| s.converged_value).collect();
        let strag: u64 = stats_v.iter().map(|s| s.straggler_episodes).sum();
        t.rowf(&[
            table::s(sys),
            table::f(stats::mean(&tta), 0),
            table::f(stats::mean(&jct), 0),
            table::f(stats::mean(&acc), 2),
            table::f(stats::mean(&ppl), 1),
            table::i(strag as i64),
            table::s(format!("{}/{}", tta.len(), stats_v.len())),
        ]);
    }
    t.print();
    Ok(())
}
